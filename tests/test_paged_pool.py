"""Paged block-KV pool: allocator invariants (alloc/free/reuse,
fragmentation, partition property), block-table flash-decode vs the oracle,
engine parity on the paged path (incl. int8), pool-exhaustion parking and
livelock-breaking eviction, and the seq-sharded paged combine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode, flash_decode_xla
from repro.models.layers.attention import _quant_kv
from repro.models.registry import get_model
from repro.serve import ForecastEngine, Request
from repro.serve.cache_pool import (BlockAllocator, PagedCachePool,
                                    auto_block_size)

CACHE_LEN = 48


@pytest.fixture(scope="module")
def dense():
    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    return cfg, api, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


def _solo_greedy(api, cfg, params, prompt, gen, cache_len=CACHE_LEN):
    from repro.launch.steps import make_serve_step
    cache, logits = api.prefill(
        params, cfg, {"tokens": jnp.asarray(prompt[None])},
        cache_len=cache_len)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
    out = [int(tok[0, 0])]
    P = len(prompt)
    for i in range(gen - 1):
        tok, cache = serve(params, cache,
                           {"token": tok,
                            "pos": jnp.asarray([P + i], jnp.int32)})
        out.append(int(tok[0, 0]))
    return out


# ---------------------------------------------------------------------------
# allocator (host-side, no model)
# ---------------------------------------------------------------------------

def test_allocator_alloc_free_reuse_ordering():
    a = BlockAllocator(4)
    assert a.alloc(2) == [0, 1]               # LIFO free list pops low first
    assert a.alloc(1) == [2]
    a.free([1])
    assert a.alloc(1) == [1]                  # freed block is reused next
    assert a.free_blocks == 1 and a.used_blocks == 3
    with pytest.raises(RuntimeError):         # exhausted: 2 > 1 free
        a.alloc(2)
    assert a.free_blocks == 1                 # failed alloc takes nothing
    a.free([0])
    with pytest.raises(ValueError):
        a.free([0])                           # double-free
    with pytest.raises(ValueError):
        a.free([99])                          # never allocated


def test_allocator_fragmentation_after_staggered_retirement():
    """Interleaved grants from three requests, middle one retires: its
    scattered blocks go back whole and satisfy a new multi-block alloc."""
    a = BlockAllocator(9)
    rows = {r: [] for r in "abc"}
    for _ in range(3):                        # a,b,c round-robin: b's blocks
        for r in "abc":                       # are non-contiguous (1,4,7)
            rows[r] += a.alloc(1)
    assert a.free_blocks == 0
    assert rows["b"] == [1, 4, 7]
    a.free(rows["b"])                         # staggered retirement
    got = a.alloc(3)                          # refill from the holes
    assert sorted(got) == [1, 4, 7]
    assert a.free_blocks == 0


def test_free_runs_and_fragmentation_gauge():
    a = BlockAllocator(8)
    assert a.free_runs == 1 and a.fragmentation == 0.0   # [0..7] contiguous
    held = a.alloc(8)
    assert a.free_runs == 0 and a.fragmentation == 0.0   # nothing free
    a.free([held[1]])
    assert a.free_runs == 1 and a.fragmentation == 0.0   # single block
    a.free([held[3], held[5]])                           # holes: {1,3,5}
    assert a.free_runs == 3
    assert a.fragmentation == pytest.approx(2 / 2)       # fully shredded
    a.free([held[2]])                                    # {1,2,3,5}: 2 runs
    assert a.free_runs == 2
    assert a.fragmentation == pytest.approx(1 / 3)
    a.free([held[0], held[4], held[6], held[7]])         # all free again
    assert a.free_runs == 1 and a.fragmentation == 0.0


def test_fragmentation_bounded_under_churn():
    rng = np.random.default_rng(11)
    a = BlockAllocator(24)
    held = []
    for _ in range(200):
        if rng.integers(2) and a.free_blocks:
            held += a.alloc(int(rng.integers(1, a.free_blocks + 1)))
        elif held:
            k = int(rng.integers(1, len(held) + 1))
            take = [held.pop(int(rng.integers(len(held))))
                    for _ in range(k)]
            a.free(take)
        assert 0.0 <= a.fragmentation <= 1.0
        assert a.free_runs <= max(a.free_blocks, 1)


def test_engine_metrics_fragmentation_summary():
    from repro.serve.metrics import EngineMetrics
    m = EngineMetrics(num_slots=2)
    for frag in (0.0, 0.5, 0.25, 1.0):
        m.record_decode_step(1, 1, 0.01, fragmentation=frag)
    s = m.summary()
    assert s["mean_fragmentation"] == pytest.approx(0.4375)
    assert s["peak_fragmentation"] == 1.0


def _partition_holds(a: BlockAllocator):
    free = set(a._free)
    assert len(free) == len(a._free), "duplicate in free list"
    assert free.isdisjoint(a._used)
    assert free | a._used == set(range(a.n_blocks))


def _drive(a: BlockAllocator, ops):
    held = []
    for want_alloc, amount in ops:
        if want_alloc:
            n = 1 + amount % max(a.free_blocks, 1)
            if n <= a.free_blocks:
                held += a.alloc(n)
        elif held:
            k = 1 + amount % len(held)
            a.free(held[:k])
            held = held[k:]
        _partition_holds(a)


def test_partition_invariant_seeded():
    """Free list + allocations always partition the pool (seeded sweep —
    runs even without hypothesis)."""
    rng = np.random.default_rng(7)
    for _ in range(20):
        a = BlockAllocator(int(rng.integers(1, 24)))
        ops = [(bool(rng.integers(2)), int(rng.integers(100)))
               for _ in range(40)]
        _drive(a, ops)


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=1, max_value=32),
       st.lists(st.tuples(st.booleans(),
                          st.integers(min_value=0, max_value=999)),
                max_size=60))
def test_partition_invariant_property(n_blocks, ops):
    _drive(BlockAllocator(n_blocks), ops)


def test_auto_block_size_divides():
    for ring in (25, 48, 96, 128, 1, 97, 31, 14):
        bs = auto_block_size(ring)
        assert ring % bs == 0
    assert auto_block_size(96) == 16          # divisor nearest the target
    assert auto_block_size(48) == 16
    assert auto_block_size(25) == 25          # 1/5/25: 25 is closest to 16
    # prime rings must NOT degenerate to block_size=1 (table length ==
    # ring_len, single-token scatters): the min-tile clamp picks the whole
    # ring as one block instead
    assert auto_block_size(97) == 97
    assert auto_block_size(31) == 31
    assert auto_block_size(14) == 14          # 2 and 7 sit below the clamp
    assert auto_block_size(4) == 4            # tiny rings keep working


# ---------------------------------------------------------------------------
# pool lifecycle (device arrays, no model forward)
# ---------------------------------------------------------------------------

def test_paged_pool_lifecycle(dense):
    cfg, _, _ = dense
    pool = PagedCachePool(cfg, num_slots=3, cache_len=32, block_size=8)
    assert pool.blocks_per_slot == 4 and pool.pool_blocks == 12
    s = pool.acquire()
    pool.grant_prefix(s, 2)
    pool.grant(s, 2)
    assert pool.blocks_in_use == 3
    pool.assert_partition()
    with pytest.raises(ValueError):           # logical block 2 already held
        pool.grant(s, 2)
    pool.release(s)                           # frees all three
    assert pool.blocks_in_use == 0
    pool.assert_partition()
    with pytest.raises(ValueError):
        pool.release(s)
    # geometry guards
    with pytest.raises(ValueError, match="divide"):
        PagedCachePool(cfg, num_slots=1, cache_len=32, block_size=5)
    ssm = get_smoke_config("xlstm-350m")
    with pytest.raises(ValueError, match="uniform ring"):
        PagedCachePool(ssm, num_slots=1, cache_len=32)


def test_submit_rejects_unservable_footprint(dense):
    """A request whose ring footprint exceeds the whole pool would park
    forever — reject it at submit, not mid-decode."""
    cfg, _, params = dense
    eng = ForecastEngine(cfg, params, num_slots=2, cache_len=CACHE_LEN,
                         paged=True, block_size=8, pool_blocks=2)
    with pytest.raises(ValueError, match="blocks"):
        eng.submit(Request(id="big", prompt=np.zeros(20, np.int32),
                           max_new_tokens=20))


# ---------------------------------------------------------------------------
# block-table flash decode vs oracle
# ---------------------------------------------------------------------------

def _paged_case(int8, seed=0, nb=12, bs=16, Hk=2, G=4, D=32, B=3, T=4):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, 1, Hk * G, D))
    k = jax.random.normal(ks[1], (nb, bs, Hk, D))
    v = jax.random.normal(ks[2], (nb, bs, Hk, D))
    kw = {}
    if int8:
        k, ksc = _quant_kv(k)
        v, vsc = _quant_kv(v)
        kw = dict(k_scale=ksc, v_scale=vsc)
    # non-contiguous physical blocks, ungranted holes, ragged fill levels
    tbl = jnp.asarray([[7, 2, 9, 0], [4, 5, -1, -1], [11, 3, 8, -1]],
                      jnp.int32)[:B]
    q_pos = np.asarray([T * bs - 1, 2 * bs - 1, 2 * bs + 5])[:B]
    kv_pos = np.full((nb, bs), -1, np.int32)
    for b in range(B):
        for j in range(T):
            pb = int(tbl[b, j])
            if pb < 0:
                continue
            for o in range(bs):
                if j * bs + o <= q_pos[b]:
                    kv_pos[pb, o] = j * bs + o
    return (q, k, v, jnp.asarray(kv_pos), tbl,
            jnp.asarray(q_pos, jnp.int32), kw)


@pytest.mark.parametrize("int8", [False, True])
def test_paged_flash_decode_matches_oracle(int8):
    q, k, v, kv_pos, tbl, q_pos, kw = _paged_case(int8, seed=int(int8))
    o_r = ref.flash_decode_ref(q, k, v, kv_pos, q_pos, block_tables=tbl,
                               **kw)
    o_p = flash_decode(q, k, v, kv_pos, q_pos, block_tables=tbl,
                       n_splits=2, interpret=True, **kw)
    o_x = flash_decode_xla(q, k, v, kv_pos, q_pos, block_tables=tbl, **kw)
    tol = 3e-2 if int8 else 1e-5
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_r), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(o_x), np.asarray(o_r), rtol=tol,
                               atol=tol)


def test_paged_gather_is_bit_identical_to_ring():
    """A fully-granted identity-layout table reproduces the contiguous ring
    EXACTLY — the invariant behind paged == contiguous greedy decode."""
    B, S, Hk, D, bs = 2, 48, 2, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, 1, Hk * 2, D))
    k = jax.random.normal(ks[1], (B, S, Hk, D))
    v = jax.random.normal(ks[2], (B, S, Hk, D))
    kv_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    pos = jnp.asarray(S - 1, jnp.int32)
    # pool = the two rings stacked block-wise; per-row identity tables
    T = S // bs
    kp = k.reshape(B * T, bs, Hk, D)
    vp = v.reshape(B * T, bs, Hk, D)
    pp = kv_pos.reshape(B * T, bs)
    tbl = jnp.arange(B * T, dtype=jnp.int32).reshape(B, T)
    ring = flash_decode_xla(q, k, v, kv_pos, pos)
    paged = flash_decode_xla(q, kp, vp, pp, pos, block_tables=tbl)
    assert np.array_equal(np.asarray(ring), np.asarray(paged))


def test_sharded_paged_decode_on_emulated_mesh():
    """Block axis sharded over ``model``: per-shard localized tables +
    pmax/psum combine must match the unsharded paged path.  Subprocess —
    the device-count flag must precede jax init."""
    import os
    import subprocess
    import sys
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.dist.decode import sharded_flash_decode, seq_shard_mesh
from repro.kernels.flash_decode import flash_decode_xla

nb, bs, Hk, G, D, B, T = 16, 16, 2, 4, 32, 4, 4
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (B, 1, Hk * G, D))
k = jax.random.normal(ks[1], (nb, bs, Hk, D))
v = jax.random.normal(ks[2], (nb, bs, Hk, D))
# blocks deliberately straddle both model shards; row 3 inactive (pos -1);
# rows 0 and 2 SHARE physical block 0 at the same logical index (a CoW
# prefix-share grant) — per-entry localization must resolve both sharers
# to the same stripe-local tile
tbl = jnp.asarray([[0, 8, 1, 9], [15, 2, -1, -1], [0, 12, 5, -1],
                   [3, 11, 6, 14]], jnp.int32)
pos = jnp.asarray([T * bs - 1, 2 * bs - 5, 2 * bs + 7, -1], jnp.int32)
kv_pos = np.full((nb, bs), -1, np.int32)
for b in range(B):
    for j in range(T):
        pb = int(tbl[b, j])
        if pb < 0: continue
        for o in range(bs):
            if j * bs + o <= int(pos[b]):
                kv_pos[pb, o] = j * bs + o
kv_pos = jnp.asarray(kv_pos)
mesh = jax.make_mesh((2, 2), ("data", "model"))
with mesh:
    assert seq_shard_mesh(nb) is not None
    out = sharded_flash_decode(q, k, v, kv_pos, pos, mesh,
                               block_tables=tbl)
want = flash_decode_xla(q, k, v, kv_pos, pos, block_tables=tbl)
np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                           rtol=1e-5, atol=1e-5)
assert np.all(np.asarray(out)[3] == 0.0)
print("SHARDED_PAGED_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_CACHE_SHARD", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0 and "SHARDED_PAGED_OK" in r.stdout, \
        r.stdout + "\n" + r.stderr


# ---------------------------------------------------------------------------
# engine on the paged pool
# ---------------------------------------------------------------------------

def test_paged_engine_matches_solo(dense):
    """Staggered trace through a genuinely paged pool (6 blocks/lane) is
    bit-identical to each request alone, in ONE serve_step signature, with
    the partition invariant intact at every retirement."""
    cfg, api, params = dense
    prompts = _prompts(cfg, [6, 9, 6, 11], seed=21)
    gens = [5, 3, 6, 4]
    ref_out = [_solo_greedy(api, cfg, params, p, g)
               for p, g in zip(prompts, gens)]
    eng = ForecastEngine(cfg, params, num_slots=2, cache_len=CACHE_LEN,
                         paged=True, block_size=8)
    assert eng.paged and eng.pool.blocks_per_slot == 6
    for i, (p, g) in enumerate(zip(prompts, gens)):
        eng.submit(Request(id=f"r{i}", prompt=p, max_new_tokens=g,
                           arrival_step=i))
    done = eng.run(max_steps=500)
    for i in range(len(prompts)):
        assert done[f"r{i}"].tokens.tolist() == ref_out[i], i
    assert eng.num_step_signatures() == 1
    assert eng.pool.blocks_in_use == 0
    eng.pool.assert_partition()
    assert eng.metrics.summary()["mean_block_utilization"] > 0


def test_paged_engine_int8(dense, monkeypatch):
    monkeypatch.setenv("REPRO_KV_INT8", "1")
    cfg, api, params = dense
    prompts = _prompts(cfg, [6, 9], seed=23)
    ref_out = [_solo_greedy(api, cfg, params, p, 4) for p in prompts]
    eng = ForecastEngine(cfg, params, num_slots=2, cache_len=CACHE_LEN,
                         paged=True, block_size=8)
    assert any(l.dtype == jnp.int8 for l in jax.tree.leaves(eng.pool.cache))
    for i, p in enumerate(prompts):
        eng.submit(Request(id=f"r{i}", prompt=p, max_new_tokens=4,
                           arrival_step=i))
    done = eng.run(max_steps=200)
    for i in range(len(prompts)):
        assert done[f"r{i}"].tokens.tolist() == ref_out[i], i


def test_pool_exhaustion_parks_without_corruption(dense):
    """An oversubscribed pool (5 blocks for two 3-block requests) must park
    the request that can't grow — and once the neighbour retires and frees
    blocks, the parked request resumes and BOTH outputs stay bit-identical
    to solo decode (a parked lane never corrupts a neighbour)."""
    cfg, api, params = dense
    prompts = _prompts(cfg, [6, 6], seed=25)
    gen = 16                                  # positions reach block 2 of 8
    ref_out = [_solo_greedy(api, cfg, params, p, gen) for p in prompts]
    eng = ForecastEngine(cfg, params, num_slots=2, cache_len=CACHE_LEN,
                         paged=True, block_size=8, pool_blocks=5)
    eng.submit(Request(id="r0", prompt=prompts[0], max_new_tokens=gen))
    eng.submit(Request(id="r1", prompt=prompts[1], max_new_tokens=gen,
                       arrival_step=2))
    done = eng.run(max_steps=500)
    for i in range(2):
        assert done[f"r{i}"].tokens.tolist() == ref_out[i], i
    assert eng.metrics.parked_events >= 1
    assert eng.metrics.evictions == 0
    eng.pool.assert_partition()


def test_simultaneous_exhaustion_evicts_and_recomputes(dense):
    """Both residents hit the block wall on the same step: the youngest is
    evicted back onto the queue (prompt + generated) and recomputed once
    blocks free — greedy outputs still bit-identical to solo.  This pins
    the recompute FALLBACK, so the swap tier (which would displace without
    evicting) is explicitly off; tests/test_prefix_share.py covers the
    swap-tier version of the same squeeze."""
    cfg, api, params = dense
    prompts = _prompts(cfg, [6, 6], seed=27)
    gen = 16
    ref_out = [_solo_greedy(api, cfg, params, p, gen) for p in prompts]
    # max_tokens_in_flight exactly fits both ORIGINAL footprints: the
    # evicted request's resumed form must not inflate its budget (its
    # prompt absorbs generated tokens the horizon already counts) or it
    # could never re-admit and run() would spin forever
    eng = ForecastEngine(cfg, params, num_slots=2, cache_len=CACHE_LEN,
                         paged=True, block_size=8, pool_blocks=4,
                         max_tokens_in_flight=2 * (6 + gen),
                         swap_tier=False)
    eng.submit(Request(id="r0", prompt=prompts[0], max_new_tokens=gen))
    eng.submit(Request(id="r1", prompt=prompts[1], max_new_tokens=gen))
    done = eng.run(max_steps=500)
    for i in range(2):
        assert done[f"r{i}"].tokens.tolist() == ref_out[i], i
    assert eng.metrics.evictions >= 1
    assert done["r1"].prompt_len == 6         # reports the ORIGINAL prompt
    eng.pool.assert_partition()


def test_paged_admits_more_than_lane_capacity(dense):
    """The point of paging: at pool bytes worth 2 contiguous lanes, short
    requests run >2-wide because they only pin the blocks they fill."""
    cfg, api, params = dense
    prompts = _prompts(cfg, [5, 5, 5, 5, 5], seed=29)
    gen = 4                                   # footprint 9 tokens = 2 blocks
    ref_out = [_solo_greedy(api, cfg, params, p, gen) for p in prompts]
    # pool bytes == 2 lanes x 48 slots == 12 blocks of 8; 5 lanes share them
    eng = ForecastEngine(cfg, params, num_slots=5, cache_len=CACHE_LEN,
                         paged=True, block_size=8, pool_blocks=12)
    for i, p in enumerate(prompts):
        eng.submit(Request(id=f"r{i}", prompt=p, max_new_tokens=gen))
    done = eng.run(max_steps=300)
    for i in range(len(prompts)):
        assert done[f"r{i}"].tokens.tolist() == ref_out[i], i
    assert eng.metrics.peak_in_flight > 2     # beyond lane-equivalent bytes
    assert eng.num_step_signatures() == 1
