"""Optional-hypothesis shim: property tests skip when hypothesis is absent,
while plain tests in the same module keep running.

``from hypothesis_compat import given, settings, st`` — with hypothesis
installed these are the real objects; without it, ``given`` marks the
decorated test skipped and ``st``'s strategy constructors return inert
placeholders that only ever flow into that skip decorator.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
