"""dist/fed.py <-> core/comm.py agreement: the roofline collective term and
the paper's Fig. 5 comm metric must be the same quantity measured two ways
(DESIGN.md §3 — federation mapped onto mesh collectives)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.core import comm
from repro.core.lora import attach_lora, lora_tree, tree_nbytes
from repro.dist import fed
from repro.dist.sharding import param_specs
from repro.launch.mesh import PRODUCTION_MESH_SHAPES

SINGLE = PRODUCTION_MESH_SHAPES["single"]
MULTI = PRODUCTION_MESH_SHAPES["multi"]


@pytest.fixture(scope="module")
def fed_params():
    """Abstract LoRA-attached param tree (no allocation)."""
    cfg = get_smoke_config("qwen3-0.6b")
    ft = cfg.fedtime

    def build(key):
        from repro.models.registry import get_model
        p = get_model(cfg).init(cfg, key)
        return attach_lora(p, key, rank=ft.lora_rank, alpha=ft.lora_alpha)

    return jax.eval_shape(build, jax.ShapeDtypeStruct((2,), jnp.uint32))


def test_ring_allreduce_formula():
    assert fed.ring_allreduce_bytes(1000, 1) == 0
    # divisible payloads reduce to the classic 2*P*(n-1)/n
    assert fed.ring_allreduce_bytes(1024, 2) == 1024          # 2*P*(1/2)
    assert fed.ring_allreduce_bytes(4096, 4) == 6144          # 2*P*(3/4)
    # non-divisible payloads pay their real chunk padding (the old float
    # formula silently truncated): 250 elems -> 4 chunks of 63
    assert fed.ring_allreduce_bytes(1000, 2) == 4 * 63 * 4
    # 400 elems over n=16 -> 32 chunks of 13, 60 sends
    assert fed.ring_allreduce_bytes(1600, 16) == 60 * 13 * 4
    # quantized wire: int8 codes + one f32 scale per REPRO_FED_QBLOCK block
    from repro.core.comm import ring_wire_plan
    plan = ring_wire_plan(1 << 16, 4, "int8", qblock=128)
    assert fed.ring_allreduce_bytes(1 << 18, 4, wire="int8") == \
        plan.per_device_bytes
    assert plan.scale_bytes == 4 * (plan.chunk_elems // 128)


def test_aggregation_axes():
    assert fed.aggregation_axes(SINGLE) == ("data",)
    assert fed.aggregation_axes(MULTI) == ("data", "pod")
    assert fed.aggregation_axes({"model": 16}) == ()


@pytest.mark.parametrize("mesh_shape", [SINGLE, MULTI],
                         ids=["single_pod", "multi_pod"])
def test_fed_mapping_matches_comm_accounting(fed_params, mesh_shape):
    """The ring all-reduce bytes implied by fed.py's psum axis mapping must
    equal core/comm's per-axis accounting, axis by axis."""
    expected = fed.expected_collective_bytes(fed_params, mesh_shape)
    accounted = comm.collective_bytes_per_round(fed_params, mesh_shape)
    assert expected == accounted
    # sanity: the single-pod round moves ~2*P*(15/16) per device over data
    # (exact chunk plan — never less than the idealized continuous formula)
    payload = tree_nbytes(lora_tree(fed_params))
    assert expected["data"] == fed.ring_allreduce_bytes(payload, 16)
    assert expected["data"] >= int(2 * payload * 15 / 16)


def test_comm_accounting_accepts_mesh_object(fed_params):
    class FakeMesh:
        shape = dict(MULTI)

    assert comm.collective_bytes_per_round(fed_params, FakeMesh()) == \
        comm.collective_bytes_per_round(fed_params, MULTI)


def test_lora_payload_is_replicated(fed_params):
    """Precondition for the pure-psum aggregation: every adapter leaf must
    be replicated by the sharding rules on the production mesh."""
    specs = param_specs(fed_params, SINGLE)

    def walk(tree, path=()):
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, path + (k,))
        elif path[-1] in ("lora_a", "lora_b", "lora_scale"):
            assert tree == P(), path

    walk(specs)
    # the tree is non-trivial: at least one adapter pair exists
    assert len(jax.tree.leaves(lora_tree(fed_params))) > 0


def test_aggregate_adapters_weighted_mean():
    """Algorithm 1 line 12: aggregation is the cluster-size-weighted mean."""
    rng = np.random.default_rng(0)
    n = 4
    members = {"wq": {"lora_a": rng.normal(size=(n, 3, 8, 2)),
                      "lora_b": rng.normal(size=(n, 3, 2, 8))}}
    members = jax.tree.map(jnp.asarray, members)
    weights = np.array([0.4, 0.3, 0.2, 0.1])

    out = fed.aggregate_adapters(members, weights)
    ref = jax.tree.map(
        lambda a: np.tensordot(weights, np.asarray(a), axes=1), members)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6),
        out, ref)

    # aggregating identical members with normalized weights is the identity
    same = jax.tree.map(lambda a: jnp.broadcast_to(a[:1], a.shape), members)
    out = fed.aggregate_adapters(same, np.full((n,), 1.0 / n))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b[0], rtol=1e-6),
                 out, same)


def test_aggregate_adapters_on_mesh():
    """The shard_map/psum path, on whatever devices this host has (the
    federation axis collapses to size 1 on a single-device CPU, making the
    psum trivial but still exercising the collective lowering)."""
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    n = 4 * n_dev
    a = jnp.arange(n * 6, dtype=jnp.float32).reshape(n, 2, 3)
    w = jnp.full((n,), 1.0 / n)
    out = fed.aggregate_adapters({"lora_a": a}, w, mesh)
    np.testing.assert_allclose(np.asarray(out["lora_a"]),
                               np.asarray(a).mean(axis=0), rtol=1e-6)
