"""Fleet-scale telemetry: the per-client round ledger (staleness clock,
wire-byte roll-up, two-rule straggler flagging, fleet.json schema), the
crash-dump flight recorder (ring semantics, tracer-off capture, forced
eviction post-mortem), device-memory snapshots, and per-scope HLO cost
attribution.  Includes the ISSUE acceptance run: a 64-client federated fit
whose per-cluster summed wire bytes equal the comm accounting exactly and
whose injected slow client is flagged as a straggler."""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs import get_smoke_config
from repro.obs import devmem
from repro.obs import flight as flight_mod
from repro.obs.fleet import SCHEMA, FleetLedger
from repro.obs.flight import FlightRecorder


# ---------------------------------------------------------------------------
# ledger unit behaviour
# ---------------------------------------------------------------------------

def test_staleness_clock_only_advances_on_participation():
    led = FleetLedger()
    assert led.record(0, 0, 7).staleness == 0          # first sighting
    assert led.record(1, 0, 7).staleness == 1
    # excluded in rounds 2-3: records exist, clock does NOT advance
    assert led.record(2, 0, 7, participated=False).staleness == 1
    assert led.record(3, 0, 7, participated=False).staleness == 2
    assert led.record(4, 0, 7).staleness == 3          # aged while excluded
    assert led.record(5, 0, 7).staleness == 1


def test_wire_byte_rollup_per_cluster_and_round():
    led = FleetLedger()
    for r in range(2):
        for cl, clients in ((0, (0, 1)), (1, (2, 3, 4))):
            for c in clients:
                led.record(r, cl, c, wire_bytes=100)
    led.record(1, 0, 9, wire_bytes=100, participated=False)  # skipped: free
    assert led.wire_bytes_by_cluster() == {0: 400, 1: 600}
    assert led.wire_bytes_by_cluster(round=1) == {0: 200, 1: 300}
    assert led.total_wire_bytes() == 1000
    assert led.clusters == [0, 1]


def test_straggler_rules_fire_separately_and_together():
    led = FleetLedger()
    # cluster 0: p99-only — huge outlier but zero MAD (identical peers)
    for i, w in enumerate([1.0, 1.0, 1.0, 1.0, 10.0]):
        led.record(0, 0, i, wall_s=w)
    # cluster 1: mad-only — tight spread, outlier below 2x median
    for i, w in enumerate([0.98, 1.0, 1.0, 1.02, 1.5]):
        led.record(0, 1, 10 + i, wall_s=w)
    # cluster 2: too few fits (<4): never flagged, however extreme
    for i, w in enumerate([1.0, 100.0]):
        led.record(0, 2, 20 + i, wall_s=w)
    flags = {(r.cluster, r.client): why for r, why in led.stragglers()}
    assert flags == {(0, 4): "p99", (1, 14): "mad"}
    # cluster 3: both rules — spread cluster with a >2x-median monster
    for i, w in enumerate([1.0, 1.1, 0.9, 1.05, 0.95, 8.0]):
        led.record(0, 3, 30 + i, wall_s=w)
    flags = {(r.cluster, r.client): why for r, why in led.stragglers()}
    assert flags[(3, 35)] == "p99+mad"


def test_fleet_sketch_is_merge_of_cluster_sketches():
    led = FleetLedger()
    rng = np.random.default_rng(5)
    vals = []
    for c in range(3):
        for i in range(200):
            w = float(rng.lognormal())
            led.record(0, c, c * 1000 + i, wall_s=w)
            vals.append(w)
    direct = led.cluster_sketch(0, "wall_s").copy()
    direct.merge(led.cluster_sketch(1)).merge(led.cluster_sketch(2))
    fleet = led.fleet_sketch("wall_s")
    assert fleet.count == 600
    for q in (50, 95, 99):
        assert fleet.quantile(q) == direct.quantile(q), q


def test_ledger_json_schema_and_extras():
    led = FleetLedger()
    for i in range(5):
        led.record(0, 0, i, wall_s=1.0 + i, wire_bytes=10,
                   kind="replay", tokens=8)
    led.record(0, 0, 99, participated=False)
    doc = json.loads(json.dumps(led.to_json()))        # through real JSON
    assert doc["schema"] == SCHEMA
    assert len(doc["records"]) == 6
    assert doc["records"][0]["extra"] == {"kind": "replay", "tokens": 8}
    cl = doc["clusters"]["0"]
    assert cl["clients"] == 6 and cl["fits"] == 5 and cl["skipped"] == 1
    assert cl["wire_bytes"] == 50
    assert {"count", "p50", "p99"} <= set(cl["wall_s"])
    assert doc["fleet"]["wire_bytes"] == 50
    # sketch embedded in the dump round-trips
    from repro.obs.sketch import QuantileSketch
    sk = QuantileSketch.from_dict(cl["wall_s_sketch"])
    assert sk.count == 5 and sk.max == 5.0


def test_ledger_to_trace_emits_cluster_tracks(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_TRACE", "1")
    obs.reset()
    led = FleetLedger()
    for i, w in enumerate([1.0, 1.0, 1.0, 1.0, 9.0]):
        led.record(0, 0, i, wall_s=w, wire_bytes=4, t0=100.0 + i)
    led.record(0, 1, 50, participated=False)
    led.to_trace()
    path = obs.dump(str(tmp_path / "t.json"))
    doc = json.load(open(path))
    evs = doc["traceEvents"]
    names = {e["args"]["name"] for e in evs if e["name"] == "thread_name"}
    assert {"fleet:cluster0", "fleet:cluster1"} <= names
    fits = [e for e in evs if e["name"] == "client4.fit" and e["ph"] == "X"]
    assert fits and fits[0]["args"]["straggler"] == "p99"
    skips = [e for e in evs if e["name"] == "client50.skipped"]
    assert skips and skips[0]["ph"] == "i"


# ---------------------------------------------------------------------------
# acceptance: 64-client federated fit
# ---------------------------------------------------------------------------

def test_fed_64_clients_wire_invariant_and_straggler(tmp_path):
    """ISSUE acceptance: ≥64 clients produce a fleet.json whose per-cluster
    summed wire bytes equal the comm accounting exactly, with an injected
    slow client flagged as a straggler."""
    from repro.core import comm
    from repro.train.fed_trainer import federated_fit

    cfg = get_smoke_config("fedtime-llama2-7b")
    # every cluster member fits each round so the slow client always runs
    cfg = dataclasses.replace(
        cfg, fedtime=dataclasses.replace(cfg.fedtime, clients_per_round=64))
    ft = cfg.fedtime
    L, T, M = ft.lookback, ft.horizon, 2
    rng = np.random.default_rng(0)
    # bimodal series: k-means yields two fat clusters, so the slow client's
    # cluster always has enough fits for straggler statistics
    data = []
    for i in range(64):
        shift = 0.0 if i < 32 else 5.0
        data.append((rng.standard_normal((4, L, M)).astype(np.float32) + shift,
                     rng.standard_normal((4, T, M)).astype(np.float32) + shift))

    out = tmp_path / "fleet.json"
    res = federated_fit(cfg, data, rounds=1, batch_size=4,
                        key=jax.random.PRNGKey(0), wire="int8",
                        slow_clients={0: 0.4}, fleet_out=str(out))
    led = res.fleet
    assert len([r for r in led.records if r.participated]) == 64
    assert all(r.staleness == 0 for r in led.records)   # first sighting

    # --- the "one number, five ways" invariant, exactly -------------------
    by_cluster = led.wire_bytes_by_cluster(round=0)
    for log in res.logs:
        assert by_cluster[log.cluster] == log.comm.bytes_up, log.cluster
    n_params = comm.count_params(res.adapters_per_cluster[0])
    assert led.total_wire_bytes() == \
        64 * comm.wire_payload_bytes(n_params, "int8")

    # --- injected slow client flagged -------------------------------------
    flagged = {r.client for r, _ in led.stragglers()}
    assert 0 in flagged
    # int8 wire: every participating fit carried an EF residual norm field
    assert all(r.ef_norm >= 0.0 for r in led.records if r.participated)
    assert all(r.delta_norm > 0.0 for r in led.records if r.participated)

    # --- standalone fleet.json -------------------------------------------
    doc = json.load(open(out))
    assert doc["schema"] == SCHEMA
    assert doc["fleet"]["wire_bytes"] == led.total_wire_bytes()
    assert any(s["client"] == 0 for s in doc["fleet"]["stragglers"])
    assert sum(c["fits"] for c in doc["clusters"].values()) == 64


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def test_flight_ring_retains_tail_and_counts_drops():
    fr = FlightRecorder(capacity=8)
    for i in range(20):
        fr.record("i", f"e{i}", "t", float(i))
    assert len(fr) == 8 and fr.recorded == 20
    doc = fr.to_chrome_trace("unit")
    meta = doc["metadata"]["flight_recorder"]
    assert meta == {"capacity": 8, "retained": 8, "recorded": 20,
                    "dropped": 12}
    assert doc["metadata"]["reason"] == "unit"
    kept = [e["name"] for e in doc["traceEvents"]
            if e["name"] != "thread_name"]
    assert kept == [f"e{i}" for i in range(12, 20)]    # the most recent tail


def _chrome_schema_ok(doc):
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    tids = set()
    for e in doc["traceEvents"]:
        assert e["ph"] in ("X", "i", "C", "M"), e
        assert "name" in e and "pid" in e and "tid" in e
        if e["ph"] == "M":
            tids.add(e["tid"])
        else:
            assert e["ts"] >= 0.0
            assert e["tid"] in tids            # every event on a named track
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
    assert doc["metadata"]["tool"] == "repro.obs.flight"
    fl = doc["metadata"]["flight_recorder"]
    assert fl["retained"] <= fl["capacity"]
    assert fl["dropped"] == fl["recorded"] - fl["retained"]
    return True


def test_flight_survives_mid_run_trace_toggle(monkeypatch, tmp_path):
    """The recorder's whole point: REPRO_TRACE flips to 0 mid-run and the
    events emitted while the tracer is OFF still land in a valid dump."""
    monkeypatch.setenv("REPRO_TRACE", "1")
    monkeypatch.delenv("REPRO_FLIGHT", raising=False)
    obs.reset()
    flight_mod.get_flight().reset()
    with obs.span("phase.traced", step=1):
        pass
    monkeypatch.setenv("REPRO_TRACE", "0")             # mid-run toggle
    assert not obs.enabled()
    with obs.span("phase.dark", step=2):
        pass
    obs.instant("dark.instant")
    obs.counter_track("dark.counter", v=1.0)
    out = tmp_path / "flight.json"
    monkeypatch.setenv("REPRO_FLIGHT_OUT", str(out))
    assert flight_mod.maybe_dump("toggle-test") == str(out)
    doc = json.load(open(out))
    assert _chrome_schema_ok(doc)
    names = [e["name"] for e in doc["traceEvents"]]
    # events from BOTH sides of the toggle are retained
    for want in ("phase.traced", "phase.dark", "dark.instant",
                 "dark.counter"):
        assert want in names, want
    assert doc["metadata"]["reason"] == "toggle-test"


def test_flight_disabled_records_nothing(monkeypatch):
    monkeypatch.setenv("REPRO_FLIGHT", "0")
    monkeypatch.setenv("REPRO_TRACE", "0")
    fr = flight_mod.get_flight()
    fr.reset()
    with obs.span("invisible"):
        pass
    obs.instant("invisible.i")
    assert len(fr) == 0
    # and maybe_dump with an empty ring writes nothing
    monkeypatch.setenv("REPRO_FLIGHT_OUT", "/nonexistent/nope.json")
    assert flight_mod.maybe_dump("empty") is None


def test_forced_eviction_dumps_valid_flight_trace(monkeypatch, tmp_path):
    """ISSUE acceptance: a flight dump produced by forced pool eviction
    validates against the Chrome trace schema."""
    from repro.models.registry import get_model
    from repro.serve import ForecastEngine, Request

    monkeypatch.setenv("REPRO_TRACE", "0")             # dark deployment
    monkeypatch.delenv("REPRO_FLIGHT", raising=False)
    out = tmp_path / "evict_flight.json"
    monkeypatch.setenv("REPRO_FLIGHT_OUT", str(out))
    flight_mod.get_flight().reset()

    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(27)
    prompts = [rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(2)]
    gen = 16
    eng = ForecastEngine(cfg, params, num_slots=2, cache_len=48,
                         paged=True, block_size=8, pool_blocks=4,
                         max_tokens_in_flight=2 * (6 + gen),
                         swap_tier=False)
    eng.submit(Request(id="r0", prompt=prompts[0], max_new_tokens=gen))
    eng.submit(Request(id="r1", prompt=prompts[1], max_new_tokens=gen))
    eng.run(max_steps=500)
    assert eng.metrics.evictions >= 1
    assert out.exists()                                # dump fired mid-run
    doc = json.load(open(out))
    assert _chrome_schema_ok(doc)
    assert doc["metadata"]["reason"].startswith("engine.")
    names = [e["name"] for e in doc["traceEvents"]]
    assert "req.evict" in names                        # the distress itself


# ---------------------------------------------------------------------------
# device memory + HLO scope attribution
# ---------------------------------------------------------------------------

def test_memory_snapshot_counts_live_buffers():
    x = jnp.ones((256, 4), jnp.float32)                # keep alive
    snap = devmem.memory_snapshot()
    assert set(snap) == {"bytes_in_use", "peak_bytes_in_use", "bytes_limit",
                         "live_buffer_bytes", "live_buffers"}
    assert snap["live_buffer_bytes"] >= x.nbytes
    assert snap["live_buffers"] >= 1
    assert devmem.peak_bytes() >= x.nbytes


def test_watermark_emits_gauges_and_counter_track(monkeypatch):
    monkeypatch.setenv("REPRO_TRACE", "1")
    obs.reset()
    keep = jnp.zeros((64, 64), jnp.float32)
    snap = devmem.watermark("unit")
    assert snap["live_buffer_bytes"] >= keep.nbytes
    tr = obs.get_tracer()
    # on CPU bytes_in_use falls back to the live-buffer footprint
    assert tr.gauges["devmem.unit.bytes_in_use"] >= float(keep.nbytes)
    # the counter-track sample landed on the flight recorder too
    assert any(name == "devmem" and ph == "C"
               for ph, name, *_ in flight_mod.get_flight()._buf)


def test_scope_costs_attributes_named_scopes():
    def f(x, w):
        with jax.named_scope("obs.proj"):
            y = x @ w
        return y + 1.0                                 # unscoped epilogue

    x = jnp.ones((16, 32), jnp.float32)
    w = jnp.ones((32, 8), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    costs = devmem.compiled_scope_costs(compiled)
    assert costs is not None and "obs.proj" in costs
    # the dot's FLOPs land in the named scope: 2*M*K*N
    assert costs["obs.proj"]["flops"] >= 2 * 16 * 32 * 8
    assert costs["obs.proj"]["bytes"] > 0
    other = sum(v["flops"] for k, v in costs.items() if k != "obs.proj")
    assert other < costs["obs.proj"]["flops"]          # dot dominates


def test_scope_costs_on_dispatch_kernel():
    """The kernels' own ``obs.*`` scopes (PR 6) are what production
    attribution keys on — rmsnorm's dispatch wrapper must show up."""
    from repro.kernels import ops
    x = jnp.ones((4, 64), jnp.float32)
    g = jnp.ones((64,), jnp.float32)
    compiled = jax.jit(lambda a, b: ops.rmsnorm(a, b)).lower(x, g).compile()
    costs = devmem.compiled_scope_costs(compiled)
    assert costs and "obs.rmsnorm" in costs
    assert costs["obs.rmsnorm"]["ops"] >= 1
