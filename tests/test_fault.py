"""Fault-tolerant federated rounds (repro.fault + resilient round loop).

Covers the chaos harness end to end: virtual-clock fault plans (no
``time.sleep`` anywhere), deadline-bounded partial participation with
correct weight renormalization, staleness-bounded async buffering,
corrupt/byzantine upload rejection, exact secure-aggregation dropout
recovery on the int8 wire, crash-safe checkpoints, and mid-round crash
recovery (in-process and via a real kill-9 subprocess), plus the
64-client chaos acceptance run from ISSUE.md.
"""

import dataclasses
import itertools
import os
import signal
import subprocess
import sys
import time

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import secure_agg
from repro.core.server import BufferedDelta, StalenessBuffer
from repro.fault import (Fault, FaultPlan, VirtualClock, load_round_state,
                         save_round_state, validate_deltas)
from repro.train import checkpoint
from repro.train.fed_trainer import federated_fit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mini(n_clients=8, *, clusters=2, per_round=None, seed=0):
    """Smoke config + bimodal client data (k-means splits low/high)."""
    cfg = get_smoke_config("fedtime-llama2-7b")
    cfg = dataclasses.replace(cfg, fedtime=dataclasses.replace(
        cfg.fedtime, num_clusters=clusters,
        clients_per_round=per_round or n_clients))
    ft = cfg.fedtime
    rng = np.random.default_rng(seed)
    data = []
    for i in range(n_clients):
        shift = 0.0 if i < n_clients // 2 else 5.0
        data.append(
            (rng.standard_normal((4, ft.lookback, 2)).astype(np.float32)
             + shift,
             rng.standard_normal((4, ft.horizon, 2)).astype(np.float32)
             + shift))
    return cfg, data


def _reasons(ledger, client=None):
    return [((r.extra or {}).get("reason"), r.round) for r in ledger.records
            if not r.participated and (client is None or r.client == client)]


# ---------------------------------------------------------------------------
# virtual clock + fault plans
# ---------------------------------------------------------------------------

def test_virtual_clock():
    clk = VirtualClock()
    assert clk.now() == 0.0
    clk.advance(1.5)
    clk.advance_to(1.0)                    # never goes backward
    assert clk.now() == 1.5
    clk.advance_to(4.0)
    assert clk.now() == 4.0
    with pytest.raises(ValueError):
        clk.advance(-1.0)


def test_fault_plan_timing_and_determinism():
    plan = FaultPlan({
        1: [Fault("delay", delay_s=2.0)],
        2: [Fault("transient", fails=2, backoff_s=0.25)],
        3: [Fault("crash")],
        4: [Fault("hang")],
    }, base_fit_s=1.0)
    assert plan.attempt(0, 0, 99.0).virtual_s == 1.0   # base_fit_s overrides
    assert plan.attempt(1, 0, 0.0).virtual_s == 3.0
    # two failed attempts: (1 + .25) + (1 + .5), then the good one
    att = plan.attempt(2, 0, 0.0)
    assert att.virtual_s == pytest.approx(3.75) and att.retries == 2
    assert not plan.attempt(3, 0, 0.0).uploads
    assert np.isinf(plan.attempt(4, 0, 0.0).virtual_s)
    assert not plan.will_upload(3, 0) and not plan.will_upload(4, 0)
    assert plan.will_upload(2, 0)

    a = FaultPlan.random(32, 0.3, 4, seed=7)
    b = FaultPlan.random(32, 0.3, 4, seed=7)
    assert a.faults == b.faults            # bit-identical replay
    assert FaultPlan.random(32, 0.3, 4, seed=8).faults != a.faults


def test_fault_plan_rounds_scoping():
    plan = FaultPlan({0: [Fault("crash", rounds=frozenset({1}))]})
    assert plan.will_upload(0, 0) and not plan.will_upload(0, 1)
    assert plan.kinds_for(0, 1) == ("crash",)


def test_validate_deltas_guard():
    good = {"w": np.ones(4, np.float32)}
    nan = {"w": np.asarray([np.nan, 1, 1, 1], np.float32)}
    big = {"w": np.full(4, 1e4, np.float32)}
    out = validate_deltas([good, good, good, nan, big], byz_k=25.0)
    assert [ok for ok, _, _ in out] == [True, True, True, False, False]
    assert out[3][1] == "corrupt" and out[4][1] == "byzantine"


def test_staleness_buffer_unit():
    buf = StalenessBuffer(limit=2, decay=0.5)
    d = {"w": np.ones(2, np.float32)}
    buf.add(BufferedDelta(1, 0, 0, ready_at=1.0, weight=4.0, loss=0.1,
                          delta=d))
    buf.add(BufferedDelta(2, 0, 0, ready_at=9.0, weight=1.0, loss=0.1,
                          delta=d))
    buf.add(BufferedDelta(3, 1, 0, ready_at=1.0, weight=1.0, loss=0.1,
                          delta=d))                    # other cluster
    apply, reject = buf.drain(0, 1, window_end=2.0)
    assert [(e.client, w) for e, w in apply] == [(1, 2.0)]  # 4.0 * 0.5**1
    assert not reject and len(buf) == 2
    apply, reject = buf.drain(0, 5, window_end=100.0)  # staleness 5 >= 2
    assert not apply and [(e.client, s) for e, s in reject] == [(2, 5)]
    with pytest.raises(ValueError):
        buf.add(BufferedDelta(9, 0, 0, ready_at=float("inf"), weight=1.0,
                              loss=0.0, delta=d))      # hung uploads never buffer


# ---------------------------------------------------------------------------
# resilient round loop: exclusion, buffering, rejection — all on the
# virtual clock (each of these completes in seconds of WALL time)
# ---------------------------------------------------------------------------

def test_slow_clients_shim_runs_without_sleeping():
    """The legacy slow_clients kwarg now rides the virtual clock: a
    30-virtual-second straggler must not cost 30 wall seconds, but must
    still be flagged by the fleet ledger."""
    cfg, data = _mini(8)
    t0 = time.monotonic()
    res = federated_fit(cfg, data, rounds=1, batch_size=4,
                        key=jax.random.PRNGKey(0),
                        slow_clients={0: 30.0})
    assert time.monotonic() - t0 < 25.0        # virtual, not slept
    rec0 = [r for r in res.fleet.records if r.client == 0][0]
    assert rec0.participated and rec0.wall_s > 30.0
    assert 0 in {r.client for r, _ in res.fleet.stragglers()}


def test_crash_and_hang_excluded_with_reasons():
    cfg, data = _mini(6, clusters=1)
    plan = FaultPlan({0: [Fault("crash")], 1: [Fault("hang")]},
                     base_fit_s=1.0)
    res = federated_fit(cfg, data, rounds=2, batch_size=4,
                        key=jax.random.PRNGKey(0), fault_plan=plan,
                        deadline_s=10.0)
    led = res.fleet
    assert sorted(_reasons(led, 0)) == [("crash", 0), ("crash", 1)]
    assert sorted(_reasons(led, 1)) == [("hang", 0), ("hang", 1)]
    # the 4 healthy clients aggregated every round, renormalized
    for r in (0, 1):
        assert sum(1 for rec in led.records
                   if rec.round == r and rec.participated) == 4
    assert len(res.logs) == 2
    assert all(np.isfinite(l.train_loss) for l in res.logs)
    assert led.rejections_by_reason() == {"crash": 2, "hang": 2}


def test_deadline_buffering_then_staleness_apply():
    """A delayed upload misses its window, parks in the staleness buffer,
    and applies two rounds later with decayed weight."""
    cfg, data = _mini(6, clusters=1)
    plan = FaultPlan({2: [Fault("delay", delay_s=2.5,
                                rounds=frozenset({0}))]},
                     base_fit_s=0.5)
    res = federated_fit(cfg, data, rounds=4, batch_size=4,
                        key=jax.random.PRNGKey(0), fault_plan=plan,
                        deadline_s=1.0, staleness_limit=3)
    led = res.fleet
    # round 0: miss (arrival 3.0 > window end 1.0) -> buffered
    assert ("deadline", 0) in _reasons(led, 2)
    # drained at the first window whose end >= 3.0 (round 2), staleness 2
    # (strictly inside limit 3 — staleness == limit rejects, see the
    # boundary test below)
    drained = [r for r in led.records
               if r.client == 2 and r.participated and r.extra
               and "buffered_staleness" in r.extra]
    assert [(r.round, r.extra["buffered_staleness"]) for r in drained] \
        == [(2, 2)]


def test_deadline_buffering_then_stale_reject():
    cfg, data = _mini(6, clusters=1)
    plan = FaultPlan({2: [Fault("delay", delay_s=2.5,
                                rounds=frozenset({0}))]},
                     base_fit_s=0.5)
    res = federated_fit(cfg, data, rounds=4, batch_size=4,
                        key=jax.random.PRNGKey(0), fault_plan=plan,
                        deadline_s=1.0, staleness_limit=1)
    led = res.fleet
    assert ("deadline", 0) in _reasons(led, 2)
    assert ("stale", 2) in _reasons(led, 2)     # staleness 2 >= limit 1
    assert not any(r.participated and r.extra
                   and "buffered_staleness" in r.extra
                   for r in led.records if r.client == 2)


def test_staleness_limit_boundary_rejects_on_both_paths():
    """staleness == staleness_limit must reject on BOTH paths — the
    buffer's own drain predicate and the trainer's apply filter — so a
    delta never applies on one path that the other would have rejected.
    Historically drain used ``>`` while apply used ``>=``; the shared
    ``is_stale`` predicate pins the exclusive boundary."""
    # path 1: StalenessBuffer.drain at the exact boundary
    buf = StalenessBuffer(limit=2, decay=0.5)
    d = {"w": np.ones(2, np.float32)}
    buf.add(BufferedDelta(7, 0, 0, ready_at=1.0, weight=1.0, loss=0.1,
                          delta=d))
    assert buf.is_stale(2) and not buf.is_stale(1)
    assert buf.staleness_of(2, 0) == 2 == buf.staleness_of(1, 0) + 1
    apply, reject = buf.drain(0, 2, window_end=5.0)   # staleness exactly 2
    assert not apply and [(e.client, s) for e, s in reject] == [(7, 2)]
    # path 2: the trainer's cohort filter — same delay scenario as the
    # apply test above but with limit == achieved staleness (2): the
    # buffered delta must surface as a "stale" rejection, never apply
    cfg, data = _mini(6, clusters=1)
    plan = FaultPlan({2: [Fault("delay", delay_s=2.5,
                                rounds=frozenset({0}))]},
                     base_fit_s=0.5)
    res = federated_fit(cfg, data, rounds=4, batch_size=4,
                        key=jax.random.PRNGKey(0), fault_plan=plan,
                        deadline_s=1.0, staleness_limit=2)
    led = res.fleet
    assert ("deadline", 0) in _reasons(led, 2)
    assert ("stale", 2) in _reasons(led, 2)     # staleness 2 == limit 2
    assert not any(r.participated and r.extra
                   and "buffered_staleness" in r.extra
                   for r in led.records if r.client == 2)


def test_corrupt_and_byzantine_never_aggregate():
    cfg, data = _mini(6, clusters=1)
    plan = FaultPlan({0: [Fault("corrupt")],
                      3: [Fault("byzantine", scale=1e3)]},
                     base_fit_s=1.0)
    res = federated_fit(cfg, data, rounds=2, batch_size=4,
                        key=jax.random.PRNGKey(0), fault_plan=plan,
                        wire="int8")
    led = res.fleet
    assert led.rejections_by_reason() == {"corrupt": 2, "byzantine": 2}
    # zero NaN/corrupt deltas applied: the server state stays finite
    for ad in res.adapters_per_cluster:
        assert all(bool(np.all(np.isfinite(np.asarray(l))))
                   for l in jax.tree.leaves(ad))
    assert all(np.isfinite(l.train_loss) for l in res.logs)
    # rejected uploads carry their bytes per-record but stay out of the
    # "one number" sums (only aggregated uploads are metered)
    rej = [r for r in led.records if not r.participated]
    assert all(r.wire_bytes > 0 for r in rej)
    by_cluster = led.wire_bytes_by_cluster()
    assert by_cluster[0] == sum(l.comm.bytes_up for l in res.logs)


def test_transient_retries_delay_arrival():
    cfg, data = _mini(6, clusters=1)
    plan = FaultPlan({1: [Fault("transient", fails=2, backoff_s=0.25)]},
                     base_fit_s=1.0)
    res = federated_fit(cfg, data, rounds=1, batch_size=4,
                        key=jax.random.PRNGKey(0), fault_plan=plan)
    rec = [r for r in res.fleet.records if r.client == 1][0]
    assert rec.participated and rec.wall_s == pytest.approx(3.75)


# ---------------------------------------------------------------------------
# secure aggregation dropout recovery (exact, int8 wire)
# ---------------------------------------------------------------------------

def test_secure_masks_cancel_exactly_for_every_surviving_subset():
    """ISSUE satellite: pairwise masks cancel bit-exactly for EVERY
    surviving subset over the integer wire."""
    participants = [3, 7, 11, 20, 5]
    rng = np.random.default_rng(0)
    codes = {p: rng.integers(-127, 128, size=33).astype(np.int32)
             for p in participants}
    masked = {p: secure_agg.mask_codes(codes[p], client_id=p,
                                       participants=participants,
                                       round_idx=4)
              for p in participants}
    for k in range(1, len(participants) + 1):
        for survivors in itertools.combinations(participants, k):
            got = secure_agg.unmask_sum([masked[s] for s in survivors],
                                        list(survivors),
                                        participants=participants,
                                        round_idx=4)
            want = sum(codes[s] for s in survivors)
            assert np.array_equal(got, want), survivors


def test_secure_encode_error_feedback_composes():
    """Shared-grid EF: residual stays bounded and the carried error makes
    the two-round cumulative dequant converge on the true sum."""
    step = 2.0 ** -10
    rng = np.random.default_rng(1)
    x = (rng.standard_normal(257) * 0.01).astype(np.float32)
    c1, r1 = secure_agg.secure_encode(x, None, step=step)
    assert np.max(np.abs(r1)) <= step / 2 + 1e-7   # in-range: no clip error
    c2, r2 = secure_agg.secure_encode(x, r1, step=step)
    two_rounds = (c1 + c2).astype(np.float32) * np.float32(step)
    np.testing.assert_allclose(two_rounds + r2, 2 * x, atol=1e-6)


def test_secure_dropout_recovery_bit_exact_vs_unmasked():
    """Masked-with-recovery pipeline == plain partial aggregate, bit for
    bit, after dequantization."""
    participants = [0, 1, 2, 3]
    step = secure_agg.default_step()
    rng = np.random.default_rng(2)
    flats = {p: (rng.standard_normal(65) * 0.02).astype(np.float32)
             for p in participants}
    codes, masked = {}, {}
    for p in participants:
        codes[p], _ = secure_agg.secure_encode(flats[p], None, step=step)
        masked[p] = secure_agg.mask_codes(codes[p], client_id=p,
                                          participants=participants,
                                          round_idx=0)
    survivors = [0, 2, 3]                     # client 1 dropped mid-round
    got = secure_agg.secure_decode_sum(
        secure_agg.unmask_sum([masked[s] for s in survivors], survivors,
                              participants=participants, round_idx=0),
        step=step)
    want = secure_agg.secure_decode_sum(sum(codes[s] for s in survivors),
                                        step=step)
    assert got.dtype == np.float32 and np.array_equal(got, want)


def test_secure_fit_survives_dropout():
    """End-to-end: secure int8 aggregation with a hung client — the
    server recovers the dropped client's masks and the round completes."""
    cfg, data = _mini(6, clusters=1)
    plan = FaultPlan({1: [Fault("hang")]}, base_fit_s=1.0)
    res = federated_fit(cfg, data, rounds=2, batch_size=4,
                        key=jax.random.PRNGKey(0), fault_plan=plan,
                        wire="int8", secure_aggregation=True,
                        deadline_s=5.0)
    led = res.fleet
    assert ("hang", 0) in _reasons(led, 1)
    assert len(res.logs) == 2
    assert all(np.isfinite(l.train_loss) for l in res.logs)
    for ad in res.adapters_per_cluster:
        assert all(bool(np.all(np.isfinite(np.asarray(l))))
                   for l in jax.tree.leaves(ad))


def test_mesh_aggregation_masks_dead_members():
    """dist.fed partial participation: a crashed member's NaN rows must
    be structurally excluded (0 * NaN = NaN — weight alone can't), and
    surviving weights renormalize to sum to 1."""
    from repro.dist import fed

    tree = {"w": np.stack([np.full((2, 3), float(i)) for i in range(4)]
                          ).astype(np.float32)}
    tree["w"][2] = np.nan                      # member 2 crashed mid-write
    weights = np.asarray([1.0, 2.0, 4.0, 1.0], np.float32)
    alive = np.asarray([1, 1, 0, 1])

    masked, w = fed.mask_members(tree, weights, alive)
    assert np.all(np.isfinite(np.asarray(masked["w"])))
    np.testing.assert_allclose(np.asarray(w), [0.25, 0.5, 0.0, 0.25])

    out = fed.aggregate_adapters(tree, weights, mesh=None, alive=alive)
    want = 0.25 * 0.0 + 0.5 * 1.0 + 0.25 * 3.0
    np.testing.assert_allclose(np.asarray(out["w"]), want, rtol=1e-6)


# ---------------------------------------------------------------------------
# crash-safe checkpoints
# ---------------------------------------------------------------------------

def test_checkpoint_atomic_no_tmp_residue(tmp_path):
    tree = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": {"c": np.ones(5, dtype=np.int32)}}
    p = tmp_path / "ck.msgpack.zst"
    n = checkpoint.save(str(p), tree)
    assert n > 0 and p.exists()
    assert [f for f in os.listdir(tmp_path) if ".tmp." in f] == []
    back = checkpoint.load(str(p))
    np.testing.assert_array_equal(np.asarray(back["a"]), tree["a"])


def test_checkpoint_refuses_truncation_and_corruption(tmp_path):
    p = tmp_path / "ck.msgpack.zst"
    checkpoint.save(str(p), {"a": np.arange(100, dtype=np.float32)})
    raw = p.read_bytes()

    trunc = tmp_path / "trunc.ckpt"
    trunc.write_bytes(raw[:-7])
    with pytest.raises(ValueError, match="truncated checkpoint"):
        checkpoint.load(str(trunc))

    corr = tmp_path / "corr.ckpt"
    body = bytearray(raw)
    body[-3] ^= 0xFF
    corr.write_bytes(bytes(body))
    with pytest.raises(ValueError, match="CRC mismatch"):
        checkpoint.load(str(corr))


def test_checkpoint_legacy_headerless_still_loads(tmp_path):
    p = tmp_path / "new.ckpt"
    tree = {"a": np.arange(7, dtype=np.float32)}
    checkpoint.save(str(p), tree)
    legacy = tmp_path / "legacy.ckpt"
    legacy.write_bytes(p.read_bytes()[20:])     # strip the header
    back = checkpoint.load(str(legacy))
    np.testing.assert_array_equal(np.asarray(back["a"]), tree["a"])


def test_round_state_snapshot_roundtrip(tmp_path):
    p = str(tmp_path / "snap.ckpt")
    arrays = {"servers": {"0": {"w": np.ones((2, 3), np.float32)}}}
    meta = {"round": 3, "rng": {"state": 2 ** 100}}   # 128-bit-safe
    save_round_state(p, arrays, meta)
    m, a = load_round_state(p)
    assert m["round"] == 3 and m["rng"]["state"] == 2 ** 100
    np.testing.assert_array_equal(np.asarray(a["servers"]["0"]["w"]),
                                  arrays["servers"]["0"]["w"])
    with pytest.raises(FileNotFoundError):
        load_round_state(str(tmp_path / "missing.ckpt"))


# ---------------------------------------------------------------------------
# mid-round crash recovery
# ---------------------------------------------------------------------------

def _leaves(res):
    return [np.asarray(l) for ad in res.adapters_per_cluster
            for l in jax.tree.leaves(ad)]


def test_snapshot_resume_bit_identical_in_process(tmp_path):
    """Stop after round 1, resume from the snapshot, and land bit-for-bit
    on the uninterrupted run's state."""
    cfg, data = _mini(8)
    plan = FaultPlan.random(8, 0.25, 3, seed=1)     # deterministic timeline
    kw = dict(rounds=3, batch_size=4, key=jax.random.PRNGKey(0),
              fault_plan=plan, deadline_s=2.0, wire="int8")

    full = federated_fit(cfg, data, **kw)

    snap = str(tmp_path / "snap.ckpt")
    federated_fit(cfg, data, **{**kw, "rounds": 2}, snapshot_path=snap)
    resumed = federated_fit(cfg, data, **kw, snapshot_path=snap,
                            resume=True)

    for a, b in zip(_leaves(full), _leaves(resumed)):
        assert a.dtype == b.dtype and np.array_equal(a, b)
    assert len(full.logs) == len(resumed.logs)
    assert [l.train_loss for l in full.logs] == \
        [l.train_loss for l in resumed.logs]
    assert len(full.fleet.records) == len(resumed.fleet.records)


_CHILD = """
import dataclasses, os, signal, sys
import numpy as np, jax
sys.path.insert(0, os.path.join({repo!r}, "src"))
from repro.configs import get_smoke_config
from repro.fault import FaultPlan
from repro.train.fed_trainer import federated_fit

mode, out = sys.argv[1], sys.argv[2]
cfg = get_smoke_config("fedtime-llama2-7b")
cfg = dataclasses.replace(cfg, fedtime=dataclasses.replace(
    cfg.fedtime, num_clusters=2, clients_per_round=8))
ft = cfg.fedtime
rng = np.random.default_rng(0)
data = []
for i in range(8):
    shift = 0.0 if i < 4 else 5.0
    data.append(
        (rng.standard_normal((4, ft.lookback, 2)).astype(np.float32) + shift,
         rng.standard_normal((4, ft.horizon, 2)).astype(np.float32) + shift))

plan = FaultPlan.random(8, 0.25, 3, seed=1)
kw = dict(rounds=3, batch_size=4, key=jax.random.PRNGKey(0),
          fault_plan=plan, deadline_s=2.0, wire="int8")
snap = os.path.join(out, "snap.ckpt")

done = [0]
def killer(msg):
    done[0] += 1
    if done[0] == 3:       # kill-9 mid round 1, right after (1, cluster 0)
        os.kill(os.getpid(), signal.SIGKILL)

if mode == "crash":
    federated_fit(cfg, data, **kw, snapshot_path=snap, progress=killer)
elif mode == "resume":
    res = federated_fit(cfg, data, **kw, snapshot_path=snap, resume=True)
elif mode == "full":
    res = federated_fit(cfg, data, **kw)
if mode in ("resume", "full"):
    leaves = [np.asarray(l) for ad in res.adapters_per_cluster
              for l in jax.tree.leaves(ad)]
    np.savez(os.path.join(out, mode + ".npz"),
             losses=np.asarray([l.train_loss for l in res.logs]),
             **{{str(i): l for i, l in enumerate(leaves)}})
"""


def test_kill9_mid_round_resumes_bit_identical(tmp_path):
    """ISSUE acceptance: a server killed with SIGKILL mid-run resumes the
    same round from its snapshot and finishes bit-identically to an
    uninterrupted run."""
    script = tmp_path / "child.py"
    script.write_text(_CHILD.format(repo=REPO))
    env = {**os.environ, "REPRO_TRACE": "0"}

    def run(mode):
        return subprocess.run([sys.executable, str(script), mode,
                               str(tmp_path)], env=env, timeout=560)

    crashed = run("crash")
    assert crashed.returncode == -signal.SIGKILL    # actually kill-9'd
    assert (tmp_path / "snap.ckpt").exists()
    assert run("resume").returncode == 0
    assert run("full").returncode == 0

    a = np.load(tmp_path / "resume.npz")
    b = np.load(tmp_path / "full.npz")
    assert set(a.files) == set(b.files)
    for k in b.files:
        assert np.array_equal(a[k], b[k]), k


# ---------------------------------------------------------------------------
# chaos acceptance: 64 clients, >=20% faults, deadline-bounded rounds
# ---------------------------------------------------------------------------

def test_chaos_64_clients_converges_within_tolerance():
    """ISSUE acceptance: 64 clients with >=20% injected faults of every
    kind, every round deadline-bounded, zero NaN applied, and the final
    loss within 10% of the fault-free baseline."""
    cfg, data = _mini(64)
    plan = FaultPlan.random(64, 0.25, 3, seed=3, base_fit_s=1.0)
    assert plan.fault_rate(64) >= 0.20
    kinds = {f.kind for fs in plan.faults.values() for f in fs}
    assert kinds == {"crash", "hang", "transient", "corrupt", "byzantine"}

    deadline = 3.0
    chaos = federated_fit(cfg, data, rounds=3, batch_size=4,
                          key=jax.random.PRNGKey(0), fault_plan=plan,
                          deadline_s=deadline, wire="int8")
    clean = federated_fit(cfg, data, rounds=3, batch_size=4,
                          key=jax.random.PRNGKey(0), wire="int8")

    led = chaos.fleet
    # faults actually fired and were audited
    rej = led.rejections_by_reason()
    assert sum(rej.values()) > 0 and set(rej) <= {
        "crash", "hang", "deadline", "corrupt", "byzantine", "stale"}
    # every on-time aggregated upload landed inside its window
    for r in led.records:
        if r.participated and not (r.extra or {}).get("buffered_staleness"):
            assert r.wall_s <= deadline + 1e-9
    # zero NaN/corrupt deltas applied
    for ad in chaos.adapters_per_cluster:
        assert all(bool(np.all(np.isfinite(np.asarray(l))))
                   for l in jax.tree.leaves(ad))
    # the ledger's "one number" invariant holds under faults too
    by_cluster = led.wire_bytes_by_cluster()
    want = {}
    for log in chaos.logs:
        want[log.cluster] = want.get(log.cluster, 0) + log.comm.bytes_up
    assert by_cluster == want

    def final_loss(res):
        last = max(l.round for l in res.logs)
        return float(np.mean([l.train_loss for l in res.logs
                              if l.round == last]))

    lf, lc = final_loss(chaos), final_loss(clean)
    assert np.isfinite(lf) and np.isfinite(lc)
    assert abs(lf - lc) <= 0.10 * abs(lc), (lf, lc)
