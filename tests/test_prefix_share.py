"""Copy-on-write prefix sharing + host swap tier for the paged KV pool:
refcounted allocator invariants (hypothesis property over share/CoW/free
sequences), the pool's prefix-chain index lifecycle, the Pallas block-copy
kernel, shared tables through the decode kernels, engine-level greedy
parity (cluster-skewed traces, full-prompt prefill skips), swap-out /
swap-in bit-exactness vs never-swapped lanes, and FIFO requeue ordering
for multi-victim ticks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.kernels import ref
from repro.kernels.flash_decode import (flash_decode, flash_decode_xla,
                                        paged_block_copy)
from repro.models.registry import get_model
from repro.serve import ForecastEngine, Request
from repro.serve.cache_pool import BlockAllocator, PagedCachePool
from repro.serve.scheduler import FIFOScheduler

CACHE_LEN = 48


@pytest.fixture(scope="module")
def dense():
    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    return cfg, api, params


def _solo_greedy(api, cfg, params, prompt, gen, cache_len=CACHE_LEN):
    from repro.launch.steps import make_serve_step
    cache, logits = api.prefill(
        params, cfg, {"tokens": jnp.asarray(prompt[None])},
        cache_len=cache_len)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
    out = [int(tok[0, 0])]
    P = len(prompt)
    for i in range(gen - 1):
        tok, cache = serve(params, cache,
                           {"token": tok,
                            "pos": jnp.asarray([P + i], jnp.int32)})
        out.append(int(tok[0, 0]))
    return out


# ---------------------------------------------------------------------------
# refcounted allocator (host-side, no model)
# ---------------------------------------------------------------------------

def test_allocator_refcount_lifecycle():
    a = BlockAllocator(4)
    b0, b1 = a.alloc(2)
    assert a.refcount(b0) == 1
    assert a.incref(b0) == 2
    with pytest.raises(ValueError, match="shared"):
        a.free([b0])                          # shared blocks never free()
    assert not a.decref(b0)                   # still one owner left
    assert a.refcount(b0) == 1
    assert a.decref(b0)                       # last ref -> back to free list
    assert a.refcount(b0) == 0
    with pytest.raises(ValueError):
        a.decref(b0)                          # double-free
    with pytest.raises(ValueError):
        a.incref(b0)                          # can't share a free block
    a.free([b1])                              # exclusive free still works
    assert a.free_blocks == 4


def _check_share_partition(a: BlockAllocator, rows):
    """Free list + rows partition the pool; refcount == row citations."""
    held = {}
    for r in rows:
        for b in r:
            held[b] = held.get(b, 0) + 1
    free = set(a._free)
    assert len(free) == len(a._free), "duplicate in free list"
    assert free.isdisjoint(held), "block both free and cited"
    assert free | set(held) == set(range(a.n_blocks)), "block leaked"
    assert set(held) == a._used
    for b, c in held.items():
        assert a.refcount(b) == c, (b, a.refcount(b), c)


def _drive_share(a: BlockAllocator, ops):
    """Model a lane table as rows of block ids; exercise alloc / share
    (incref) / CoW (alloc+decref) / release (decref row)."""
    rows = []
    for op, x, y in ops:
        if op == 0:                            # admit: alloc 1-3 blocks
            n = 1 + x % 3
            if n <= a.free_blocks:
                rows.append(a.alloc(n))
        elif op == 1 and rows:                 # share a row into a new lane
            src = rows[x % len(rows)]
            for b in src:
                a.incref(b)
            rows.append(list(src))
        elif op == 2 and rows:                 # CoW one shared block
            r = rows[x % len(rows)]
            j = y % len(r)
            if a.refcount(r[j]) > 1 and a.free_blocks >= 1:
                fresh = a.alloc(1)[0]
                assert not a.decref(r[j])      # donor still holds it
                r[j] = fresh
        elif op == 3 and rows:                 # retire a lane
            for b in rows.pop(x % len(rows)):
                a.decref(b)
        _check_share_partition(a, rows)
    for r in rows:                             # drain: nothing leaks
        for b in r:
            a.decref(b)
    _check_share_partition(a, [])
    assert a.free_blocks == a.n_blocks


def test_share_partition_invariant_seeded():
    rng = np.random.default_rng(11)
    for _ in range(20):
        a = BlockAllocator(int(rng.integers(1, 24)))
        ops = [(int(rng.integers(4)), int(rng.integers(100)),
                int(rng.integers(100))) for _ in range(60)]
        _drive_share(a, ops)


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=1, max_value=32),
       st.lists(st.tuples(st.integers(min_value=0, max_value=3),
                          st.integers(min_value=0, max_value=999),
                          st.integers(min_value=0, max_value=999)),
                max_size=80))
def test_share_partition_invariant_property(n_blocks, ops):
    """Arbitrary share/CoW/free sequences preserve the free-list partition
    and never double-free or leak a refcounted block."""
    _drive_share(BlockAllocator(n_blocks), ops)


# ---------------------------------------------------------------------------
# pool chain index + CoW (device arrays, no model forward)
# ---------------------------------------------------------------------------

def _fake_ring(pool, valid, seed=0):
    """Batch-1 prefill-shaped leaves with recognizable random data and the
    first ``valid`` ring slots valid."""
    rng = np.random.default_rng(seed)
    L = pool.cache["kv_pos"].shape[0]
    ring = {k: jnp.asarray(
        rng.standard_normal((p.shape[0], 1, pool.ring_len) + p.shape[3:]),
        p.dtype) for k, p in pool.cache.items()}
    pos = np.broadcast_to(np.arange(pool.ring_len, dtype=np.int32),
                          (L, 1, pool.ring_len)).copy()
    pos[..., valid:] = -1
    ring["kv_pos"] = jnp.asarray(pos)
    return ring


def test_pool_share_cow_chain_lifecycle(dense):
    cfg, _, _ = dense
    pool = PagedCachePool(cfg, num_slots=3, cache_len=48, block_size=8,
                          pool_blocks=10)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 22).astype(np.int32)

    s0 = pool.acquire()
    pool.grant_tail(s0, 0, pool.blocks_for(22))
    pool.insert(_fake_ring(pool, 22), s0)
    pool.register_prefix(s0, prompt, logits_row=np.arange(8, dtype=np.float32))

    # whole-prompt hit returns the full chain + stored logits
    blocks, full, logits = pool.match_prefix(prompt)
    assert full and len(blocks) == 3 and logits is not None
    # block-aligned partial hit (divergent tail)
    tail = np.concatenate([prompt[:16],
                           rng.integers(0, cfg.vocab_size, 6)
                           .astype(np.int32)])
    pblocks, pfull, plogits = pool.match_prefix(tail)
    assert not pfull and plogits is None and len(pblocks) == 2
    assert pblocks == blocks[:2]
    # no hit at all
    assert pool.match_prefix(rng.integers(0, cfg.vocab_size, 22)
                             .astype(np.int32)) == ([], False, None)

    s1 = pool.acquire()
    pool.share_map(s1, blocks)
    assert [pool.refcount(b) for b in blocks] == [2, 2, 2]
    assert pool.blocks_in_use == 3             # zero new blocks
    pool.assert_partition()

    before = {k: np.asarray(v[:, blocks[2]]) for k, v in pool.cache.items()}
    old, new = pool.cow(s1, 2)
    assert old == blocks[2] and pool.refcount(old) == 1 \
        and pool.refcount(new) == 1
    pool.assert_partition()
    for k, v in pool.cache.items():            # tile copied verbatim
        assert np.array_equal(np.asarray(v[:, new]), before[k]), k

    # retiring the sharer leaves the donor's chain intact...
    pool.release(s1)
    pool.assert_partition()
    assert pool.match_prefix(prompt)[1]
    # ...retiring the donor kills every chain citing its blocks
    pool.release(s0)
    pool.assert_partition()
    assert pool.match_prefix(prompt) == ([], False, None)
    assert pool.match_prefix(tail) == ([], False, None)
    assert pool.free_blocks == 10 and not pool._chains \
        and not pool._block_chains


def test_pool_wrap_write_invalidates_chain(dense):
    """A sole owner wrapping its ring over indexed prefix content must drop
    the chain entries citing the overwritten block."""
    cfg, _, _ = dense
    pool = PagedCachePool(cfg, num_slots=2, cache_len=16, block_size=8)
    prompt = np.arange(12, dtype=np.int32)
    s = pool.acquire()
    pool.grant_tail(s, 0, 2)
    pool.register_prefix(s, prompt, logits_row=np.zeros(4, np.float32))
    assert pool.match_prefix(prompt)[1]
    pool.invalidate_block(int(pool.table[s, 0]))   # the wrap write's block
    assert pool.match_prefix(prompt) == ([], False, None)
    pool.release(s)


def test_prompts_longer_than_ring_never_index(dense):
    cfg, _, _ = dense
    pool = PagedCachePool(cfg, num_slots=1, cache_len=16, block_size=8)
    long = np.arange(20, dtype=np.int32)       # > ring_len: wrapped away
    s = pool.acquire()
    pool.grant_tail(s, 0, 2)
    pool.register_prefix(s, long, logits_row=np.zeros(4, np.float32))
    assert not pool._chains
    assert pool.match_prefix(long) == ([], False, None)
    pool.release(s)


# ---------------------------------------------------------------------------
# block-copy kernel + shared tables through the decode kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_paged_block_copy_matches_xla(dtype):
    rng = np.random.default_rng(5)
    leaf = jnp.asarray(rng.integers(-100, 100, (3, 6, 8, 2, 4)), dtype)
    src, dst = jnp.asarray(4, jnp.int32), jnp.asarray(1, jnp.int32)
    got = paged_block_copy(leaf, src, dst, interpret=True)
    want = leaf.at[:, 1].set(leaf[:, 4])
    assert np.array_equal(np.asarray(got), np.asarray(want))
    # 2D leaves (per-slot scalars like kv_pos) take the same path
    flat = jnp.asarray(rng.integers(-5, 50, (3, 6, 8)), jnp.int32)
    got2 = paged_block_copy(flat, src, dst, interpret=True)
    assert np.array_equal(np.asarray(got2),
                          np.asarray(flat.at[:, 1].set(flat[:, 4])))


def test_shared_table_rows_match_oracle():
    """One physical block cited by several table rows (a prefix-share
    grant) must decode exactly like private copies would — the kernels
    treat tables as read-only."""
    nb, bs, Hk, G, D, B, T = 10, 16, 2, 4, 32, 3, 3
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (B, 1, Hk * G, D))
    k = jax.random.normal(ks[1], (nb, bs, Hk, D))
    v = jax.random.normal(ks[2], (nb, bs, Hk, D))
    # rows 0/1/2 share physical blocks 7 and 2 at the same logical index
    # (their common prefix); tails diverge (blocks 5, 8, ungranted)
    tbl = jnp.asarray([[7, 2, 5], [7, 2, 8], [7, 2, -1]], jnp.int32)
    q_pos = np.asarray([3 * bs - 1, 2 * bs + 7, 2 * bs - 2])
    kv_pos = np.full((nb, bs), -1, np.int32)
    for b in range(B):
        for j in range(T):
            pb = int(tbl[b, j])
            if pb < 0:
                continue
            for o in range(bs):
                if j * bs + o <= q_pos[b]:
                    kv_pos[pb, o] = max(kv_pos[pb, o], j * bs + o)
    kv_pos, q_pos = jnp.asarray(kv_pos), jnp.asarray(q_pos, jnp.int32)
    o_r = ref.flash_decode_ref(q, k, v, kv_pos, q_pos, block_tables=tbl)
    o_p = flash_decode(q, k, v, kv_pos, q_pos, block_tables=tbl,
                       n_splits=2, interpret=True)
    o_x = flash_decode_xla(q, k, v, kv_pos, q_pos, block_tables=tbl)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_r), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(o_x), np.asarray(o_r), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# engine: cluster-skewed traces, swap round trips, FIFO requeue
# ---------------------------------------------------------------------------

def _run_cluster(cfg, params, reqs, *, share, swap, pool_blocks=0,
                 slots=8, gen=8, check_queue_order=False):
    eng = ForecastEngine(cfg, params, num_slots=slots, cache_len=CACHE_LEN,
                         paged=True, block_size=8, pool_blocks=pool_blocks,
                         share_prefixes=share, swap_tier=swap)
    for r in reqs:
        eng.submit(Request(id=r["id"], prompt=r["prompt"],
                           max_new_tokens=gen,
                           arrival_step=r.get("arrival", 0)))
    while eng.scheduler.pending or eng.active_requests:
        assert eng.step_count < 500, "engine did not drain"
        eng.step()
        if check_queue_order:
            # displaced/queued requests always sit in original submit order
            seqs = [eng._seq[r.id] for r in eng.scheduler._queue]
            assert seqs == sorted(seqs), seqs
    assert eng.num_step_signatures() == 1
    eng.pool.assert_partition()
    assert eng.pool.blocks_in_use == 0
    return {k: v.tokens.tolist() for k, v in eng.finished.items()}, eng


def test_cluster_trace_share_parity(dense):
    """Two clusters of identical prompts + divergent-tail members: shared
    engine output is bit-identical to the non-shared baseline, prefill work
    drops, and share/full-hit/CoW all actually fire."""
    cfg, _, params = dense
    rng = np.random.default_rng(17)
    core = [rng.integers(0, cfg.vocab_size, 22).astype(np.int32)
            for _ in range(2)]
    reqs = []
    for c in range(2):
        for u in range(3):                     # identical replays
            reqs.append({"id": f"c{c}u{u}", "prompt": core[c],
                         "arrival": c + 2 * u})
        reqs.append({"id": f"c{c}d", "prompt": np.concatenate(
            [core[c][:16],
             rng.integers(0, cfg.vocab_size, 6).astype(np.int32)]),
            "arrival": 6})
    base, eb = _run_cluster(cfg, params, reqs, share=False, swap=False)
    shared, es = _run_cluster(cfg, params, reqs, share=True, swap=True)
    assert shared == base
    m = es.metrics
    assert m.share_hits > 0 and m.full_prompt_hits > 0 and m.cow_copies > 0
    assert m.shared_blocks > 0 and m.cow_bytes > 0
    # full-prompt hits skipped their prefills entirely
    assert m.prefill_tokens < eb.metrics.prefill_tokens
    s = m.summary()
    assert s["share_hits"] == m.share_hits
    assert s["cow_bytes"] == m.cow_bytes


def test_swap_roundtrip_matches_never_swapped(dense):
    """Identical prompts on a pool too small for simultaneous growth: lanes
    swap to host and back, never recompute, and every output matches the
    full-pool run bit-for-bit — with the queue FIFO-ordered even on
    multi-victim ticks."""
    cfg, api, params = dense
    rng = np.random.default_rng(19)
    core = rng.integers(0, cfg.vocab_size, 22).astype(np.int32)
    reqs = [{"id": f"u{i}", "prompt": core} for i in range(4)]
    base, _ = _run_cluster(cfg, params, reqs, share=False, swap=False)
    tight, eng = _run_cluster(cfg, params, reqs, share=True, swap=True,
                              pool_blocks=4, check_queue_order=True)
    assert tight == base
    m = eng.metrics
    assert m.swap_outs > 0 and m.swap_ins > 0
    assert m.evictions == 0                    # swap replaced recompute
    assert m.swap_out_bytes > 0 and m.swap_in_bytes > 0
    assert not eng.swap and not eng._swap_pending
    # TTFT measured from the ORIGINAL submit survives displacement
    for fin in eng.finished.values():
        assert fin.ttft_s >= 0


def test_swap_disabled_falls_back_to_recompute(dense):
    cfg, _, params = dense
    rng = np.random.default_rng(19)
    core = rng.integers(0, cfg.vocab_size, 22).astype(np.int32)
    reqs = [{"id": f"u{i}", "prompt": core} for i in range(4)]
    base, _ = _run_cluster(cfg, params, reqs, share=False, swap=False)
    rec, eng = _run_cluster(cfg, params, reqs, share=True, swap=False,
                            pool_blocks=4, check_queue_order=True)
    assert rec == base
    assert eng.metrics.evictions > 0 and eng.metrics.swap_outs == 0


def test_full_prompt_hit_skips_prefill_and_matches_solo(dense):
    cfg, api, params = dense
    rng = np.random.default_rng(23)
    prompt = rng.integers(0, cfg.vocab_size, 22).astype(np.int32)
    solo = _solo_greedy(api, cfg, params, prompt, 6)
    reqs = [{"id": "a", "prompt": prompt},
            {"id": "b", "prompt": prompt, "arrival": 2}]
    done, eng = _run_cluster(cfg, params, reqs, share=True, swap=True,
                             gen=6)
    assert done["a"] == solo and done["b"] == solo
    # exactly one prefill paid for the pair
    assert eng.metrics.prefill_tokens == len(prompt)
    assert eng.metrics.full_prompt_hits == 1


def test_requeue_front_batch_preserves_fifo():
    sched = FIFOScheduler()
    reqs = [Request(id=f"r{i}", prompt=np.zeros(4, np.int32),
                    max_new_tokens=2) for i in range(3)]
    sched.requeue_front(reqs)                  # one batched call
    out = sched.admit(now_step=0, free_slots=3, tokens_in_flight=0)
    assert [r.id for r in out] == ["r0", "r1", "r2"]


def test_flags_require_paged(dense):
    cfg, _, params = dense
    with pytest.raises(ValueError, match="paged"):
        ForecastEngine(cfg, params, num_slots=2, cache_len=CACHE_LEN,
                       paged=False, share_prefixes=True)
    with pytest.raises(ValueError, match="paged"):
        ForecastEngine(cfg, params, num_slots=2, cache_len=CACHE_LEN,
                       paged=False, swap_tier=True)
