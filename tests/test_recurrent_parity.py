"""Chunked-parallel vs step-by-step recurrence parity.

The strongest correctness check for the SSM/xLSTM math: the chunkwise
(training) formulations must reproduce the single-step (decode) recurrences
exactly, position by position — any error in the decay algebra, the
stabilization, or the chunk-boundary state hand-off shows up here.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.layers.mamba2 import (init_mamba2, init_mamba2_cache,
                                        mamba2_decode, mamba2_forward)
from repro.models.layers.xlstm import (init_mlstm_block, init_mlstm_cache,
                                       init_slstm_cache, mlstm_block_decode,
                                       mlstm_block_forward, slstm_block_decode,
                                       slstm_block_forward, init_slstm_block)


def test_mamba2_chunked_equals_stepwise():
    cfg = get_smoke_config("zamba2-2.7b")       # chunk_size=32
    params = init_mamba2(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 96                                # 3 chunks
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5

    y_chunked, _ = mamba2_forward(params, cfg, x)

    cache = init_mamba2_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(S):
        y_t, cache = mamba2_decode(params, cfg, x[:, t:t + 1], cache)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)

    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_step),
                               rtol=2e-3, atol=2e-3)


def test_mamba2_state_threading_across_calls():
    """forward(x) == forward(x[:half]) -> state -> forward(x[half:], state)."""
    cfg = get_smoke_config("zamba2-2.7b")
    params = init_mamba2(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 1, 64
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model)) * 0.5
    y_full, _ = mamba2_forward(params, cfg, x)
    y1, st = mamba2_forward(params, cfg, x[:, :32])
    # NOTE: state hand-off is exact only at chunk boundaries AND when the
    # conv receptive field is re-fed; use decode for the continuation.
    cache = init_mamba2_cache(cfg, B, jnp.float32)
    cache["ssm_state"] = st
    # rebuild conv tail from the chunked forward with return_cache
    _, full_cache = mamba2_forward(params, cfg, x[:, :32], return_cache=True)
    ys = []
    c = full_cache
    for t in range(32, S):
        y_t, c = mamba2_decode(params, cfg, x[:, t:t + 1], c)
        ys.append(y_t)
    y2 = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full[:, :32]), np.asarray(y1),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(y_full[:, 32:]), np.asarray(y2),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_chunked_equals_stepwise():
    cfg = get_smoke_config("xlstm-350m")        # chunk_size=32
    params = init_mlstm_block(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 96
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5

    y_chunked, _ = mlstm_block_forward(params, cfg, x)

    cache = init_mlstm_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(S):
        y_t, cache = mlstm_block_decode(params, cfg, x[:, t:t + 1], cache)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)

    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_step),
                               rtol=5e-3, atol=5e-3)


def test_slstm_forward_equals_stepwise():
    cfg = get_smoke_config("xlstm-350m")
    params = init_slstm_block(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 48
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
    y_fwd, _ = slstm_block_forward(params, cfg, x)
    cache = init_slstm_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(S):
        y_t, cache = slstm_block_decode(params, cfg, x[:, t:t + 1], cache)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_fwd), np.asarray(y_step),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_stability_under_extreme_gates():
    """Max-stabilization must keep outputs finite even with saturated
    input gates (exp(i_pre) overflows without the m-state)."""
    cfg = get_smoke_config("xlstm-350m")
    params = init_mlstm_block(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 1, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 30.0
    y, st = mlstm_block_forward(params, cfg, x)
    assert np.all(np.isfinite(np.asarray(y)))
    assert np.all(np.isfinite(np.asarray(st["C"])))


def test_mamba2_decay_bounds():
    """All SSD decay exponents are <= 0 by construction (DESIGN note):
    states cannot blow up for any input."""
    cfg = get_smoke_config("zamba2-2.7b")
    params = init_mamba2(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model)) * 50.0
    y, st = mamba2_forward(params, cfg, x)
    assert np.all(np.isfinite(np.asarray(y)))
    assert np.all(np.isfinite(np.asarray(st)))
