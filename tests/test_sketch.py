"""QuantileSketch: DDSketch accuracy guarantee on million-sample streams at
O(1) memory, exact-small fallback vs numpy, and the merge properties the
fleet ledger's per-cluster -> fleet roll-up rests on (merge == concatenated
stream, associativity, commutativity)."""

import json

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.obs.sketch import QuantileSketch, merge_all


def _sketch_of(vals, **kw):
    s = QuantileSketch(**kw)
    s.add_many(np.asarray(vals, np.float64))
    return s


# ---------------------------------------------------------------------------
# Accuracy
# ---------------------------------------------------------------------------

def test_exact_mode_matches_numpy_bitwise():
    rng = np.random.default_rng(0)
    xs = rng.normal(size=100) * 7.0
    s = _sketch_of(xs)                        # below exact_threshold
    assert s.is_exact
    for q in (0, 10, 50, 95, 99, 100):
        assert s.quantile(q) == pytest.approx(
            np.percentile(xs, q, method="linear"), rel=1e-12), q


def test_million_sample_stream_within_relative_error_at_bounded_memory():
    """The acceptance criterion: p50/p95/p99 of a 1M-sample stream within
    the documented value-relative error (rel_acc) at O(1) memory."""
    rng = np.random.default_rng(1)
    xs = rng.lognormal(mean=0.0, sigma=2.0, size=1_000_000)
    s = _sketch_of(xs, rel_acc=0.01)
    assert not s.is_exact
    assert s.count == 1_000_000
    # O(1) memory: bucket count bounded, nowhere near the stream size
    assert s.num_buckets <= s.max_buckets * 2
    assert s.num_buckets < 3000
    for q in (50, 95, 99):
        true = float(np.percentile(xs, q))
        est = s.quantile(q)
        assert abs(est - true) <= 0.011 * abs(true), (q, est, true)
    assert s.min == xs.min() and s.max == xs.max()
    assert s.mean == pytest.approx(xs.mean(), rel=1e-9)


def test_signed_and_zero_values_covered():
    xs = np.concatenate([-np.logspace(-3, 3, 400), np.zeros(200),
                         np.logspace(-3, 3, 400)])
    s = _sketch_of(xs, exact_threshold=16)    # force bucket mode
    srt = np.sort(xs)
    for q in (1, 25, 50, 75, 99):
        est = s.quantile(q)
        # the guarantee is value-relative to a sample at the target rank
        # (numpy's linear interpolation between sparse samples is not the
        # reference); accept either rank neighbour
        r = q / 100.0 * (len(srt) - 1)
        cands = [float(srt[int(np.floor(r))]), float(srt[int(np.ceil(r))])]
        assert any(abs(est - c) <= 0.011 * abs(c) + 1e-12
                   for c in cands), (q, est, cands)


def test_bucket_collapse_bounds_memory_preserving_upper_quantiles():
    xs = np.logspace(-6, 6, 50_000)           # huge dynamic range
    s = _sketch_of(xs, exact_threshold=8, max_buckets=64)
    assert s.num_buckets <= 66                # collapse holds the bound
    # collapse folds the LOW end; the straggler end stays accurate
    true = float(np.percentile(xs, 99))
    assert abs(s.quantile(99) - true) <= 0.011 * true


# ---------------------------------------------------------------------------
# Merge properties (the roll-up contract)
# ---------------------------------------------------------------------------

def test_merge_equals_concatenated_stream_exactly():
    """Spill quantizes each value independently, so merge(a, b) has
    IDENTICAL bucket content to one sketch fed a ++ b — merged quantiles
    equal concatenated-stream quantiles exactly, not just within bounds."""
    rng = np.random.default_rng(2)
    a_vals = rng.lognormal(size=5000)
    b_vals = rng.normal(size=3000) * 50.0
    m = _sketch_of(a_vals).merge(_sketch_of(b_vals))
    c = _sketch_of(np.concatenate([a_vals, b_vals]))
    for q in (0, 5, 50, 95, 99, 100):
        assert m.quantile(q) == c.quantile(q), q
    assert m.count == c.count and m._pos == c._pos and m._neg == c._neg


@settings(max_examples=30, deadline=None)
@given(
    a=st.lists(st.floats(-1e6, 1e6, allow_nan=False), max_size=300),
    b=st.lists(st.floats(-1e6, 1e6, allow_nan=False), max_size=300),
    c=st.lists(st.floats(-1e6, 1e6, allow_nan=False), max_size=300),
)
def test_merge_associative_commutative_and_matches_concat(a, b, c):
    sa, sb, sc = (_sketch_of(v, exact_threshold=64) for v in (a, b, c))
    # commutativity
    ab = sa.copy().merge(sb.copy())
    ba = sb.copy().merge(sa.copy())
    for q in (0, 25, 50, 75, 100):
        assert ab.quantile(q) == ba.quantile(q), ("comm", q)
    # associativity
    ab_c = sa.copy().merge(sb.copy()).merge(sc.copy())
    a_bc = sa.copy().merge(sb.copy().merge(sc.copy()))
    for q in (0, 25, 50, 75, 100):
        assert ab_c.quantile(q) == a_bc.quantile(q), ("assoc", q)
    # merge vs concatenated stream: identical quantiles (rank-exact)
    concat = _sketch_of(list(a) + list(b) + list(c), exact_threshold=64)
    for q in (0, 25, 50, 75, 100):
        assert ab_c.quantile(q) == concat.quantile(q), ("concat", q)
    assert ab_c.count == len(a) + len(b) + len(c)


def test_merge_rejects_mismatched_resolution():
    with pytest.raises(ValueError, match="rel_acc"):
        QuantileSketch(rel_acc=0.01).merge(QuantileSketch(rel_acc=0.02))


def test_merge_all_and_empty():
    parts = [_sketch_of(np.full(10, float(i + 1))) for i in range(4)]
    m = merge_all(parts)
    assert m.count == 40 and m.min == 1.0 and m.max == 4.0
    with pytest.raises(ValueError):
        merge_all([])
    # merging did not mutate the first part (merge_all copies)
    assert parts[0].count == 10


# ---------------------------------------------------------------------------
# Serialization + tracer integration
# ---------------------------------------------------------------------------

def test_json_roundtrip_preserves_quantiles():
    rng = np.random.default_rng(3)
    for vals in (rng.normal(size=50), rng.lognormal(size=5000)):
        s = _sketch_of(vals)
        d = json.loads(json.dumps(s.to_dict()))   # through real JSON
        r = QuantileSketch.from_dict(d)
        assert r.count == s.count
        for q in (0, 50, 99, 100):
            assert r.quantile(q) == s.quantile(q), q


def test_tracer_hist_sketch_mode(monkeypatch):
    monkeypatch.setenv("REPRO_TRACE", "1")
    from repro.obs.trace import Tracer
    tr = Tracer()
    for v in range(1, 101):
        tr.hist("fit.wall", float(v), sketch=True)
    sk = tr.sketch("fit.wall")
    assert isinstance(sk, QuantileSketch)
    assert sk.count == 100
    assert sk.quantile(50) == pytest.approx(50.5)
    assert tr.sketch("never.recorded") is None
    # plain hist names stay reservoir Histograms
    tr.hist("plain", 1.0)
    assert tr.sketch("plain") is None
