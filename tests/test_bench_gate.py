"""repro.obs.bench_gate: the regression gate must PASS on the committed
BENCH_*.json and demonstrably FAIL on perturbed baselines; row merge keeps
partial reruns from clobbering history; provenance stamps are complete."""

import copy
import json
import os

import pytest

from repro.obs import bench_gate

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _committed(suite):
    rows = bench_gate.load_bench(suite, root=_ROOT)
    if rows is None:
        pytest.skip(f"no committed BENCH_{suite}.json")
    return rows


# ---------------------------------------------------------------------------
# gates vs the committed baselines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("suite", bench_gate.BENCH_SUITES)
def test_gate_passes_on_committed_bench(suite):
    """Self-comparison of the committed file must be clean: every gated
    metric exists and satisfies its absolute bound."""
    rows = _committed(suite)
    assert bench_gate.check_suite(suite, rows, rows) == []


def test_gate_fails_on_regressed_wallclock_ratio():
    """A 10x-better baseline makes the committed kernels rows look like a
    regression — the relative-tolerance arm must trip."""
    rows = _committed("kernels")
    base = copy.deepcopy(rows)
    for r in base:
        if r.get("name") == "flash_decode_4k":
            r["speedup"] *= 10
    fails = bench_gate.check_suite("kernels", rows, base)
    assert any("flash_decode_4k" in f and "regressed" in f for f in fails)


def test_gate_fails_on_absolute_bound():
    """Bounds hold with NO baseline at all: an int8 wire fraction above the
    0.27 ceiling fails even on a first run."""
    rows = copy.deepcopy(_committed("collectives"))
    for r in rows:
        if r.get("case") == "ring" and r.get("wire") == "int8":
            r["bytes_vs_f32_psum"] = 0.5
    fails = bench_gate.check_suite("collectives", rows, None)
    assert any("ceiling" in f for f in fails)


def test_gate_exact_metrics_trip_on_any_change():
    rows = _committed("serving")
    cur = copy.deepcopy(rows)
    for r in cur:
        if r.get("name") == "serving_engine_vs_sequential":
            r["greedy_mismatches"] = 1
    fails = bench_gate.check_suite("serving", cur, rows)
    assert any("greedy_mismatches" in f for f in fails)


def test_gate_reports_missing_metric():
    fails = bench_gate.check_suite("kernels", [], None)
    assert fails and all("missing" in f for f in fails)
    report = bench_gate.gate_report({"kernels": fails, "serving": []})
    assert "GATE kernels: FAIL" in report and "GATE serving: ok" in report


def test_gate_direction_validation():
    spec = bench_gate.GateSpec({"name": "x"}, "v", "sideways")
    bench_gate.GATES["kernels"].append(spec)
    try:
        with pytest.raises(ValueError):
            bench_gate.check_suite("kernels", [{"name": "x", "v": 1}], None)
    finally:
        bench_gate.GATES["kernels"].remove(spec)


# ---------------------------------------------------------------------------
# merge + write
# ---------------------------------------------------------------------------

def test_merge_rows_replaces_in_place_and_appends():
    old = [{"row": "kernel", "name": "a", "v": 1},
           {"row": "kernel", "name": "b", "v": 2}]
    new = [{"row": "kernel", "name": "a", "v": 10},
           {"row": "kernel", "name": "c", "v": 3}]
    merged = bench_gate.merge_rows(old, new)
    assert [r["name"] for r in merged] == ["a", "b", "c"]  # stable order
    assert merged[0]["v"] == 10                            # refreshed
    assert merged[1]["v"] == 2                             # survived


def test_write_bench_merges_into_existing_file(tmp_path):
    root = str(tmp_path)
    bench_gate.write_bench("kernels", [{"name": "a", "v": 1},
                                       {"name": "b", "v": 2}],
                           full=False, root=root)
    # a partial rerun (--only) must NOT clobber row b
    path = bench_gate.write_bench("kernels", [{"name": "a", "v": 5}],
                                  full=False, root=root)
    doc = json.load(open(path))
    by_name = {r["name"]: r for r in doc["rows"]}
    assert by_name["a"]["v"] == 5 and by_name["b"]["v"] == 2
    assert doc["provenance"]["git_sha"]
    assert "env" in doc["provenance"]


def test_write_bench_survives_corrupt_file(tmp_path):
    root = str(tmp_path)
    with open(bench_gate.bench_path("serving", root), "w") as f:
        f.write("{not json")
    path = bench_gate.write_bench("serving", [{"name": "a", "v": 1}],
                                  full=True, root=root)
    doc = json.load(open(path))
    assert doc["rows"] == [{"name": "a", "v": 1}] and doc["full"] is True


def test_provenance_has_toolchain_fields():
    p = bench_gate.provenance()
    for k in ("git_sha", "jax", "jaxlib", "backend", "device_kind",
              "python", "platform", "timestamp", "env"):
        assert k in p, k
    assert isinstance(p["env"], dict)
    assert p["jax"] != "unknown"               # jax is installed here


def test_provenance_carries_device_peak_bytes():
    p = bench_gate.provenance()
    assert "device_peak_bytes" in p and p["device_peak_bytes"] >= 0


def test_provenance_drift_warns_on_cross_device_baseline():
    cur = {"backend": "cpu", "device_kind": "cpu"}
    # identical: silent
    assert bench_gate.provenance_drift(dict(cur), cur) == []
    # missing / unreadable baseline: silent (first run of a suite)
    assert bench_gate.provenance_drift(None, cur) == []
    assert bench_gate.provenance_drift({}, cur) == []
    # cross-device baseline: one warning per drifted field, not a failure
    base = {"backend": "gpu", "device_kind": "NVIDIA H100"}
    warns = bench_gate.provenance_drift(base, cur)
    assert len(warns) == 2
    assert any("backend='gpu'" in w and "backend='cpu'" in w for w in warns)
    assert any("device_kind" in w for w in warns)
    # "unknown" on either side suppresses the warning (stripped container)
    assert bench_gate.provenance_drift(
        {"backend": "unknown", "device_kind": "cpu"}, cur) == []


def test_load_provenance_reads_committed_bench(tmp_path):
    root = str(tmp_path)
    bench_gate.write_bench("kernels", [{"name": "a", "v": 1}],
                           full=False, root=root)
    prov = bench_gate.load_provenance("kernels", root)
    assert prov and prov["backend"] == bench_gate.provenance()["backend"]
    assert bench_gate.load_provenance("serving", root) is None
    bad = tmp_path / "BENCH_collectives.json"
    bad.write_text("{not json")
    assert bench_gate.load_provenance("collectives", root) is None
