"""End-to-end FedTime system tests: the federation improves the model,
the two-phase pipeline runs, baselines train, checkpoints round-trip."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import dlinear, fslstm, patchtst
from repro.configs import get_smoke_config
from repro.core import fedtime
from repro.data.federated import client_windows, partition_clients
from repro.data.timeseries import (DATASETS, generate, make_windows,
                                   train_test_split)
from repro.train.fed_trainer import federated_fit, two_phase_fit
from repro.train.trainer import evaluate_forecaster, fit


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_smoke_config("fedtime-llama2-7b")
    series = generate(DATASETS["etth1"], timesteps=2400, seed=0)
    train, test = train_test_split(series)
    clients = partition_clients(train, cfg.fedtime.num_clients, seed=0,
                                channels_per_client=2)
    cdata = client_windows(clients, cfg.fedtime.lookback,
                           cfg.fedtime.horizon, max_windows=48)
    return cfg, cdata, test


def test_federated_fit_reduces_loss(tiny_setup):
    cfg, cdata, _ = tiny_setup
    res = federated_fit(cfg, cdata, rounds=3, batch_size=8)
    by_cluster = {}
    for log in res.logs:
        by_cluster.setdefault(log.cluster, []).append(log.train_loss)
    improved = sum(1 for ls in by_cluster.values() if ls[-1] < ls[0])
    assert improved >= len(by_cluster) / 2, by_cluster


def test_federated_comm_metered_every_round(tiny_setup):
    cfg, cdata, _ = tiny_setup
    res = federated_fit(cfg, cdata, rounds=1, batch_size=8)
    assert all(l.comm.bytes_up > 0 for l in res.logs)
    assert res.total_megabytes() > 0
    assert 0 < res.trainable_frac < 0.2


def test_two_phase_pipeline_runs(tiny_setup):
    cfg, cdata, _ = tiny_setup
    res = two_phase_fit(cfg, cdata, rounds_sft=1, rounds_forecast=1,
                        dpo_steps=3, batch_size=4)
    p = res.params_for_cluster(0)
    x = jnp.asarray(cdata[0][0][:2])
    pred = fedtime.forward(p, cfg, x)
    assert pred.shape == (2, cfg.fedtime.horizon, x.shape[-1])
    assert np.all(np.isfinite(np.asarray(pred)))


def test_fedtime_beats_naive_persistence_after_training(tiny_setup):
    """Trained FedTime must beat the repeat-last-value baseline on its own
    training distribution (weak but real learning signal)."""
    cfg, cdata, _ = tiny_setup
    res = federated_fit(cfg, cdata, rounds=4, batch_size=8)
    params = res.params_for_cluster(int(res.assignments[0]))
    x, y = cdata[0]
    x, y = x[:32], y[:32]
    pred = np.asarray(fedtime.forward(params, cfg, jnp.asarray(x)))
    mse_model = float(np.mean((pred - y) ** 2))
    persist = np.repeat(x[:, -1:, :], y.shape[1], axis=1)
    mse_persist = float(np.mean((persist - y) ** 2))
    assert mse_model < mse_persist * 1.5, (mse_model, mse_persist)


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def _toy_windows(horizon=24, lookback=96):
    series = generate(DATASETS["etth2"], timesteps=1200, seed=3)
    tr, te = train_test_split(series)
    xtr, ytr = make_windows(tr, lookback, horizon, stride=4)
    xte, yte = make_windows(te, lookback, horizon, stride=8)
    return (xtr, ytr), (xte, yte)


def test_dlinear_trains():
    (xtr, ytr), (xte, yte) = _toy_windows()
    params = dlinear.init(jax.random.PRNGKey(0), 96, 24)

    def batches():
        rng = np.random.default_rng(0)
        while True:
            sel = rng.integers(0, len(xtr), 32)
            yield {"x": xtr[sel], "y": ytr[sel]}

    params, logs, _ = fit(lambda p, b: dlinear.loss(p, b), params,
                          batches(), steps=60, lr=5e-3)
    assert logs[-1].loss < logs[0].loss
    m = evaluate_forecaster(lambda p, x: dlinear.forward(p, x), params,
                            xte, yte)
    assert np.isfinite(m["mse"])


def test_fslstm_trains():
    (xtr, ytr), _ = _toy_windows()
    params = fslstm.init(jax.random.PRNGKey(0), channels=7, horizon=24,
                         d_hidden=32, layers=2)

    def batches():
        rng = np.random.default_rng(0)
        while True:
            sel = rng.integers(0, len(xtr), 16)
            yield {"x": xtr[sel], "y": ytr[sel]}

    params, logs, _ = fit(lambda p, b: fslstm.loss(p, b), params,
                          batches(), steps=30, lr=3e-3)
    assert logs[-1].loss < logs[0].loss


def test_patchtst_trains():
    (xtr, ytr), _ = _toy_windows()
    cfg = patchtst.make_config(lookback=96, horizon=24, d_model=32,
                               num_layers=2, num_heads=4, d_ff=64)
    params = patchtst.init(cfg, jax.random.PRNGKey(0), num_channels=7)

    def batches():
        rng = np.random.default_rng(0)
        while True:
            sel = rng.integers(0, len(xtr), 8)
            yield {"x": xtr[sel], "y": ytr[sel]}

    params, logs, _ = fit(lambda p, b: patchtst.loss(p, cfg, b), params,
                          batches(), steps=30, lr=1e-3)
    assert logs[-1].loss < logs[0].loss


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, tiny_setup):
    from repro.train import checkpoint
    cfg, cdata, _ = tiny_setup
    params = fedtime.init(cfg, jax.random.PRNGKey(0), num_channels=2)
    path = os.path.join(tmp_path, "ckpt.msgpack.zst")
    n = checkpoint.save(path, params)
    assert n > 0
    restored = checkpoint.load(path, like=params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_without_template(tmp_path):
    from repro.train import checkpoint
    tree = {"a": jnp.asarray([1.0, 2.0]), "b": {"c": jnp.asarray([3])}}
    path = os.path.join(tmp_path, "t.zst")
    checkpoint.save(path, tree)
    out = checkpoint.load(path)
    np.testing.assert_array_equal(np.asarray(out["a"]), [1.0, 2.0])
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]), [3])
