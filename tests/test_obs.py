"""repro.obs tracer: span nesting under threads, histogram percentiles vs
numpy, no-op overhead, Chrome trace-event schema round-trip, engine trace
validity, metrics fixes, and the federated ring-telemetry byte agreement
("one number, now four ways")."""

import json
import os
import subprocess
import sys
import threading
import time

import jax
import numpy as np
import pytest

from repro import obs
from repro.configs import get_smoke_config
from repro.models.registry import get_model
from repro.obs import bench_gate
from repro.obs.trace import _NULL_SPAN, Histogram, Tracer
from repro.serve import ForecastEngine, Request
from repro.serve.metrics import EngineMetrics


# ---------------------------------------------------------------------------
# Histogram
# ---------------------------------------------------------------------------

def test_histogram_percentiles_match_numpy_below_capacity():
    rng = np.random.default_rng(0)
    xs = rng.random(1000) * 10.0
    h = Histogram(capacity=4096)
    for x in xs:
        h.add(x)
    assert h.count == 1000
    assert h.min == xs.min() and h.max == xs.max()
    assert h.mean == pytest.approx(xs.mean(), rel=1e-12)
    for q in (0, 10, 50, 95, 99, 100):
        assert h.percentile(q) == pytest.approx(
            np.percentile(xs, q, method="linear"), rel=1e-12), q
    s = h.summary()
    assert s["p50"] == h.percentile(50) and s["p99"] == h.percentile(99)


def test_histogram_reservoir_bounded_and_sane_past_capacity():
    h = Histogram(capacity=128)
    rng = np.random.default_rng(1)
    for x in rng.random(10_000):
        h.add(x)
    assert h.count == 10_000
    assert len(h._res) == 128                 # bounded memory
    assert 0.0 <= h.min and h.max <= 1.0
    # uniform[0,1): the reservoir median is a coarse but unbiased estimate
    assert abs(h.percentile(50) - 0.5) < 0.15


def test_empty_histogram():
    h = Histogram()
    assert h.summary() == {"count": 0}
    assert h.percentile(50) == 0.0
    assert h.mean == 0.0


# ---------------------------------------------------------------------------
# Spans: nesting, threads, tracks
# ---------------------------------------------------------------------------

def test_span_nesting_and_thread_tracks():
    tr = Tracer()

    def work(tag):
        with tr.span(f"outer.{tag}", depth=0):
            time.sleep(0.002)
            with tr.span(f"inner.{tag}", depth=1):
                time.sleep(0.002)

    threads = [threading.Thread(target=work, args=(i,), name=f"wk{i}")
               for i in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    spans = {e["name"]: e for e in tr.events() if e["ph"] == "X"}
    assert len(spans) == 6
    tids = set()
    for i in range(3):
        outer, inner = spans[f"outer.{i}"], spans[f"inner.{i}"]
        # same thread -> same tid; inner nests strictly inside outer
        assert outer["tid"] == inner["tid"]
        tids.add(outer["tid"])
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1.0
    assert len(tids) == 3                     # one track per thread
    meta = [e for e in tr.events() if e["ph"] == "M"]
    assert {e["args"]["name"] for e in meta} >= {"wk0", "wk1", "wk2"}


def test_virtual_tracks_and_span_count():
    tr = Tracer()
    tr.add_span("req.lifecycle", 0.0, 1.0, track="req:a", id="a")
    tr.add_span("req.lifecycle", 0.0, 2.0, track="req:b", id="b")
    tr.instant("req.retire", track="req:a", id="a")
    assert tr.span_count("req.lifecycle") == 2
    assert tr.span_count("req.retire") == 0   # instants are not spans
    evs = [e for e in tr.events() if e.get("args", {}).get("id") == "a"]
    assert len({e["tid"] for e in evs}) == 1  # one virtual track per request


# ---------------------------------------------------------------------------
# No-op mode
# ---------------------------------------------------------------------------

def test_disabled_tracer_is_noop_and_cheap(monkeypatch):
    monkeypatch.setenv("REPRO_TRACE", "0")
    monkeypatch.setenv("REPRO_FLIGHT", "0")   # pure no-op: flight off too
    tr = Tracer()
    assert tr.span("x") is _NULL_SPAN         # shared singleton, no alloc
    n = 20_000
    t0 = time.perf_counter()
    for i in range(n):
        with tr.span("hot", step=i):
            pass
        tr.instant("i")
        tr.counter("c", 1)
        tr.hist("h", 0.5)
    per_call = (time.perf_counter() - t0) / (4 * n)
    assert tr.events() == []
    assert tr.counters == {} and tr.hists == {}
    # generous CI bound; typical is well under 1us
    assert per_call < 20e-6, f"{per_call * 1e6:.2f}us per disabled call"
    monkeypatch.setenv("REPRO_TRACE", "1")
    with tr.span("on"):
        pass
    assert tr.span_count("on") == 1           # re-enables without restart


# ---------------------------------------------------------------------------
# Chrome trace-event schema round-trip
# ---------------------------------------------------------------------------

def test_chrome_trace_schema_roundtrip(tmp_path):
    tr = Tracer()
    with tr.span("a", device=False, k=1):
        tr.instant("evt", track="t1", x=2)
    tr.counter_track("pool", blocks_in_use=3, active_lanes=1)
    tr.counter("bytes", 42)
    tr.gauge("norm", 0.5)
    tr.hist("lat", 0.01)
    path = tr.dump(str(tmp_path / "trace.json"),
                   provenance=bench_gate.provenance())
    doc = json.load(open(path))

    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and evs
    for e in evs:
        assert {"name", "ph", "pid"} <= set(e)
        if e["ph"] == "X":
            assert e["dur"] >= 0 and "ts" in e and "tid" in e
        elif e["ph"] == "i":
            assert e["s"] == "t" and "ts" in e
        elif e["ph"] == "C":
            assert all(isinstance(v, float) for v in e["args"].values())
    by_ph = {e["ph"] for e in evs}
    assert {"X", "i", "C", "M"} <= by_ph
    md = doc["metadata"]
    assert md["tool"] == "repro.obs"
    assert md["summary"]["counters"]["bytes"] == 42
    assert md["summary"]["gauges"]["norm"] == 0.5
    assert md["summary"]["hists"]["lat"]["count"] == 1
    prov = md["provenance"]
    assert {"git_sha", "jax", "backend", "device_kind", "env"} <= set(prov)


# ---------------------------------------------------------------------------
# EngineMetrics fixes
# ---------------------------------------------------------------------------

def test_metrics_wall_clock_spans_to_last_event():
    m = EngineMetrics(2)
    m.record_decode_step(2, 2, 0.001)
    m.record_finish(0.01)
    t_finish = m.last_event_at
    time.sleep(0.02)
    # decode work AFTER the last finish must advance the clock
    m.record_decode_step(1, 1, 0.001)
    assert m.last_event_at > t_finish
    s = m.summary()
    assert s["wall_s"] >= (m.last_event_at - m.started) * 0.99
    assert s["tok_per_s"] == pytest.approx(3 / s["wall_s"])


def test_metrics_steady_rate_guards_single_step():
    m = EngineMetrics(1)
    m.record_decode_step(1, 1, 5.0)           # compile-laden only step
    assert m.summary()["steady_tok_per_s"] == 0.0
    # second step: steady excludes the first step's tokens and time
    m.record_decode_step(1, 1, 0.5)
    s = m.summary()
    assert s["steady_tok_per_s"] == pytest.approx((2 * 0.5) / 0.5)


def test_metrics_latency_percentiles():
    m = EngineMetrics(4)
    m.record_decode_step(4, 4, 3.0)           # first step: excluded from ITL
    for _ in range(10):
        m.record_decode_step(4, 4, 0.01)
    for i in range(5):
        m.record_finish(0.1 * (i + 1))
    s = m.summary()
    assert m.itl_hist.count == 10             # compile step not recorded
    assert s["itl_p50_s"] == pytest.approx(0.01)
    assert s["itl_p99_s"] == pytest.approx(0.01)
    assert s["ttft_p50_s"] == pytest.approx(0.3)
    assert s["ttft_p99_s"] == pytest.approx(np.percentile(
        [0.1, 0.2, 0.3, 0.4, 0.5], 99, method="linear"), rel=1e-12)


# ---------------------------------------------------------------------------
# Engine trace validity (integration)
# ---------------------------------------------------------------------------

CACHE_LEN = 48
_LIFECYCLE = ["req.submit", "req.queued", "req.prefill", "req.first_token",
              "req.decode", "req.lifecycle", "req.retire"]


def test_engine_trace_two_request_lifecycle():
    """A 2-request staggered trace produces the exact per-request event
    sequence, one lifecycle span per finished request, and one
    engine.decode_step span per recorded decode step."""
    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(id=f"r{i}",
                    prompt=rng.integers(0, cfg.vocab_size, 6 + 3 * i)
                    .astype(np.int32),
                    max_new_tokens=4 + i, arrival_step=2 * i)
            for i in range(2)]

    obs.reset()
    eng = ForecastEngine(cfg, params, num_slots=2, cache_len=CACHE_LEN)
    for r in reqs:
        eng.submit(r)
    done = eng.run(max_steps=200)
    assert set(done) == {"r0", "r1"}

    tr = obs.get_tracer()
    events = tr.events()
    for rid in ("r0", "r1"):
        names = [e["name"] for e in events
                 if e.get("args", {}).get("id") == rid]
        assert names == _LIFECYCLE, (rid, names)
        # the whole lifecycle rides ONE virtual track
        tids = {e["tid"] for e in events
                if e.get("args", {}).get("id") == rid}
        assert len(tids) == 1, rid
    assert tr.span_count("req.lifecycle") == eng.metrics.requests_finished \
        == 2
    assert tr.span_count("engine.decode_step") == eng.metrics.decode_steps
    # the pool counter track sampled every decode step
    pool_samples = [e for e in events
                    if e["ph"] == "C" and e["name"] == "pool"]
    assert len(pool_samples) == eng.metrics.decode_steps
    # lifecycle span duration covers queued + prefill + decode
    life = {e["args"]["id"]: e for e in events
            if e["name"] == "req.lifecycle"}
    dec = {e["args"]["id"]: e for e in events if e["name"] == "req.decode"}
    for rid in ("r0", "r1"):
        assert life[rid]["dur"] >= dec[rid]["dur"]
        assert life[rid]["args"]["tokens"] == len(done[rid].tokens)
        assert life[rid]["args"]["ttft_s"] == pytest.approx(
            done[rid].ttft_s)


def test_engine_trace_valid_chrome_json(tmp_path):
    """The dump of an engine run is valid Chrome trace JSON whose
    lifecycle-span count equals requests_finished (the --trace-out
    acceptance check, in-process)."""
    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    obs.reset()
    eng = ForecastEngine(cfg, params, num_slots=2, cache_len=CACHE_LEN)
    for i in range(3):
        eng.submit(Request(
            id=f"q{i}",
            prompt=rng.integers(0, cfg.vocab_size, 5 + i).astype(np.int32),
            max_new_tokens=3))
    eng.run(max_steps=200)
    path = obs.dump(str(tmp_path / "serve_trace.json"),
                    provenance=bench_gate.provenance())
    doc = json.load(open(path))
    lifecycles = [e for e in doc["traceEvents"]
                  if e["name"] == "req.lifecycle" and e["ph"] == "X"]
    assert len(lifecycles) == eng.metrics.requests_finished == 3


# ---------------------------------------------------------------------------
# Federated ring telemetry: one number, now four ways (subprocess — the
# emulated device count must be set before jax initializes)
# ---------------------------------------------------------------------------

def _run_sub(script: str, timeout: int = 900, **env_extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_TRACE", None)
    env.update(env_extra)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


_RING_OBS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import obs
from repro.dist import fed, fedcomm

mesh = jax.make_mesh((8, 1), ("data", "model"))
axes = fed.aggregation_axes(mesh)
n = 8
rng = np.random.default_rng(0)
members = {"wq": {
    "lora_a": jnp.asarray(rng.random((n, 4, 33)).astype(np.float32)),
    "lora_b": jnp.asarray(rng.random((n, 33, 4)).astype(np.float32))}}
w = jnp.ones((n,), jnp.float32) / n
expected = fed.expected_collective_bytes(
    {"wq": {"lora_a": jax.ShapeDtypeStruct((4, 33), jnp.float32),
            "lora_b": jax.ShapeDtypeStruct((33, 4), jnp.float32)}},
    mesh, wire="int8")
ROUNDS = 3
with mesh:
    for _ in range(ROUNDS):
        fedcomm.ring_aggregate(members, w, mesh, wire="int8")
tr = obs.get_tracer()
# rounds 2..N hit the compiled-executable cache: the cached ledger must
# keep the telemetry flowing (counters scale linearly with rounds)
assert tr.counters["ring.rounds"] == ROUNDS, tr.counters
assert tr.span_count("fedcomm.ring_aggregate") == ROUNDS
for ax in axes:
    got = tr.counters[f"ring.wire_bytes.{ax}"]
    assert got == ROUNDS * expected[ax], (ax, got, expected[ax])
hops = tr.events("ring.hop")
assert hops and all(e["ph"] == "i" for e in hops)
assert sum(e["args"]["nbytes"] for e in hops) == \
    ROUNDS * sum(expected[ax] for ax in axes)
print("RING_OBS_OK")
"""


def test_ring_telemetry_matches_expected_collective_bytes():
    """The obs counter per federation axis equals
    fed.expected_collective_bytes EXACTLY, every round, including rounds
    served from the compiled-aggregation cache."""
    out = _run_sub(_RING_OBS)
    assert "RING_OBS_OK" in out


# ---------------------------------------------------------------------------
# fed_trainer round telemetry (host loop — no mesh needed)
# ---------------------------------------------------------------------------

def test_fed_trainer_round_telemetry():
    from repro.train.fed_trainer import federated_fit
    cfg = get_smoke_config("fedtime-llama2-7b")
    rng = np.random.default_rng(0)
    L, T, M, n_clients = cfg.fedtime.lookback, cfg.fedtime.horizon, 2, 4
    data = [(rng.standard_normal((6, L, M)).astype(np.float32),
             rng.standard_normal((6, T, M)).astype(np.float32))
            for _ in range(n_clients)]
    obs.reset()
    res = federated_fit(cfg, data, rounds=2, batch_size=2,
                        key=jax.random.PRNGKey(0), wire="int8")
    tr = obs.get_tracer()
    n_rounds = len(res.logs)
    assert tr.span_count("fed.round") == n_rounds
    assert tr.span_count("fed.aggregate") == n_rounds
    assert tr.span_count("fed.client_fit") >= n_rounds  # >=1 client/round
    # wire accounting mirrors the logs' metered comm exactly
    assert tr.counters["fed.wire_bytes"] == sum(
        l.comm.bytes_up + l.comm.bytes_down for l in res.logs)
    # int8 wire: every participating client carried an EF residual
    assert tr.hists["fed.ef_residual_norm"].count == \
        tr.span_count("fed.client_fit")
    # per-cluster adapter movement gauges exist for every cluster seen
    for l in res.logs:
        assert f"fed.adapter_delta_norm.cluster{l.cluster}" in tr.gauges
        assert f"fed.round_loss.cluster{l.cluster}" in tr.gauges
